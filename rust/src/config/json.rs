//! Minimal JSON parser (RFC 8259 subset sufficient for `manifest.json`).
//!
//! The vendored crate set has no `serde_json`, so this ~250-line recursive
//! descent parser is the substrate for reading the AOT manifest and for the
//! report CSV/JSON emitters.  Supports objects, arrays, strings (with
//! escapes incl. \uXXXX BMP), numbers, booleans, null.  No trailing commas,
//! no comments — exactly what `json.dump` produces.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            bail!("trailing garbage at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["k"]` with a useful error.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            other => bail!("expected number, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("expected non-negative integer, got {x}");
        }
        Ok(x as usize)
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            other => bail!("expected array, got {other:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            other => bail!("expected object, got {other:?}"),
        }
    }

    /// Convenience: array of usize (shape lists).
    pub fn as_shape(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|x| x.as_usize()).collect()
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected {:?} at byte {}, got {:?}", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, got {:?}", self.i, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, got {:?}", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                bail!("truncated \\u escape");
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let code = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        other => bail!("bad escape \\{}", other as char),
                    }
                }
                _ => {
                    // Collect the full UTF-8 sequence starting at c.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        bail!("truncated utf-8");
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| anyhow!("bad number {text:?}: {e}"))?))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

/// Serialize (used by the report module; pretty=false compact form).
pub fn to_string(v: &Json) -> String {
    let mut s = String::new();
    write_json(v, &mut s);
    s
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 1e15 {
                out.push_str(&format!("{}", *x as i64));
            } else {
                out.push_str(&format!("{x}"));
            }
        }
        Json::Str(s) => {
            out.push('"');
            for c in s.chars() {
                match c {
                    '"' => out.push_str("\\\""),
                    '\\' => out.push_str("\\\\"),
                    '\n' => out.push_str("\\n"),
                    '\r' => out.push_str("\\r"),
                    '\t' => out.push_str("\\t"),
                    c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        Json::Arr(v) => {
            out.push('[');
            for (i, x) in v.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(x, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(&Json::Str(k.clone()), out);
                out.push(':');
                write_json(x, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        assert_eq!(v.req("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.req("a").unwrap().as_arr().unwrap()[2].req("b").unwrap().as_str().unwrap(),
            "c"
        );
    }

    #[test]
    fn parse_shape_lists() {
        let v = Json::parse(r#"{"shape": [2, 4, 16, 32]}"#).unwrap();
        assert_eq!(v.req("shape").unwrap().as_shape().unwrap(), vec![2, 4, 16, 32]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{}x").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn utf8_passthrough() {
        assert_eq!(Json::parse("\"héllo→\"").unwrap(), Json::Str("héllo→".into()));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"x\n"],"b":true,"c":null}"#;
        let v = Json::parse(src).unwrap();
        let back = Json::parse(&to_string(&v)).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn prop_numbers_roundtrip() {
        crate::testing::prop("json number roundtrip", 50, |rng| {
            let x = (rng.normal() * 1e3) as f64;
            let v = Json::parse(&format!("{x}")).map_err(|e| e.to_string())?;
            match v {
                Json::Num(y) if (x - y).abs() < 1e-9 * x.abs().max(1.0) => Ok(()),
                other => Err(format!("{x} parsed as {other:?}")),
            }
        });
    }
}
