//! Minimal TOML parser — the subset run-config files need.
//!
//! Supports: `[section]` / `[section.sub]` headers, `key = value` pairs
//! with string / integer / float / boolean / homogeneous-array values, `#`
//! comments, and blank lines.  No inline tables, no multi-line strings, no
//! dates — run configs (`configs/*.toml`) don't use them.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Result<&str> {
        match self {
            TomlValue::Str(s) => Ok(s),
            other => bail!("expected string, got {other:?}"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        match self {
            TomlValue::Int(x) => Ok(*x),
            other => bail!("expected integer, got {other:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_i64()?;
        if x < 0 {
            bail!("expected non-negative, got {x}");
        }
        Ok(x as usize)
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            TomlValue::Float(x) => Ok(*x),
            TomlValue::Int(x) => Ok(*x as f64),
            other => bail!("expected float, got {other:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            TomlValue::Bool(b) => Ok(*b),
            other => bail!("expected bool, got {other:?}"),
        }
    }
}

/// `table["section"]["key"]` — flat two-level representation; dotted
/// section names keep their dots (`[a.b]` → section key `"a.b"`).
pub type TomlTable = BTreeMap<String, BTreeMap<String, TomlValue>>;

/// Parse a TOML document into sections.  Top-level keys (before any
/// `[section]`) land in the `""` section.
pub fn parse(text: &str) -> Result<TomlTable> {
    let mut table: TomlTable = BTreeMap::new();
    let mut current = String::new();
    table.entry(current.clone()).or_default();

    for (lineno, raw) in text.lines().enumerate() {
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest.strip_suffix(']')
                .with_context(|| format!("line {}: unterminated section", lineno + 1))?;
            current = name.trim().to_string();
            table.entry(current.clone()).or_default();
            continue;
        }
        let eq = line.find('=')
            .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
        let key = line[..eq].trim().trim_matches('"').to_string();
        let val = parse_value(line[eq + 1..].trim())
            .with_context(|| format!("line {}: bad value", lineno + 1))?;
        table.get_mut(&current).unwrap().insert(key, val);
    }
    Ok(table)
}

fn strip_comment(line: &str) -> &str {
    // A '#' inside a quoted string does not start a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<TomlValue> {
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').context("unterminated string")?;
        let mut out = String::new();
        let mut chars = inner.chars();
        while let Some(c) = chars.next() {
            if c == '\\' {
                match chars.next() {
                    Some('n') => out.push('\n'),
                    Some('t') => out.push('\t'),
                    Some('"') => out.push('"'),
                    Some('\\') => out.push('\\'),
                    other => bail!("bad escape \\{other:?}"),
                }
            } else {
                out.push(c);
            }
        }
        return Ok(TomlValue::Str(out));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner.strip_suffix(']').context("unterminated array")?;
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part)?);
            }
        }
        return Ok(TomlValue::Arr(items));
    }
    let clean = s.replace('_', "");
    if let Ok(x) = clean.parse::<i64>() {
        return Ok(TomlValue::Int(x));
    }
    if let Ok(x) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(x));
    }
    bail!("cannot parse value {s:?}")
}

fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_document() {
        let doc = r#"
# run config
name = "exp1"

[model]
preset = "small"
layers = 4
lr = 3e-4
use_pallas = true
ranks = [32, 16, 8]

[train.schedule]
kind = "linear"
"#;
        let t = parse(doc).unwrap();
        assert_eq!(t[""]["name"], TomlValue::Str("exp1".into()));
        assert_eq!(t["model"]["layers"], TomlValue::Int(4));
        assert_eq!(t["model"]["lr"].as_f64().unwrap(), 3e-4);
        assert_eq!(t["model"]["use_pallas"], TomlValue::Bool(true));
        assert_eq!(
            t["model"]["ranks"],
            TomlValue::Arr(vec![TomlValue::Int(32), TomlValue::Int(16), TomlValue::Int(8)])
        );
        assert_eq!(t["train.schedule"]["kind"].as_str().unwrap(), "linear");
    }

    #[test]
    fn comments_and_strings() {
        let t = parse("x = \"a # not comment\" # real comment").unwrap();
        assert_eq!(t[""]["x"].as_str().unwrap(), "a # not comment");
    }

    #[test]
    fn escapes() {
        let t = parse(r#"x = "a\nb\"c""#).unwrap();
        assert_eq!(t[""]["x"].as_str().unwrap(), "a\nb\"c");
    }

    #[test]
    fn underscored_numbers() {
        let t = parse("n = 1_000_000").unwrap();
        assert_eq!(t[""]["n"].as_i64().unwrap(), 1_000_000);
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(parse("[unclosed").is_err());
        assert!(parse("novalue").is_err());
        assert!(parse("x = @?!").is_err());
    }
}
