//! Dense host-side tensors.
//!
//! The Rust side owns all model state (parameters, optimizer moments, KV
//! caches) as plain row-major `f32`/`i32` buffers; the runtime marshals
//! them to/from PJRT literals at the execute boundary.  This is a minimal
//! substrate — just what the checkpoint format, the CLOVER transform, and
//! the coordinator need — not a general ndarray library.

use anyhow::{bail, Result};

/// Row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(),
                   "shape {:?} != data len {}", shape, data.len());
        Self { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: vec![0.0; shape.iter().product()] }
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        Self { shape: shape.to_vec(), data: vec![v; shape.iter().product()] }
    }

    pub fn scalar(v: f32) -> Self {
        Self { shape: vec![], data: vec![v] }
    }

    /// Identity matrix n×n.
    pub fn eye(n: usize) -> Self {
        let mut t = Self::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    pub fn item(&self) -> f32 {
        assert_eq!(self.data.len(), 1, "item() on non-scalar {:?}", self.shape);
        self.data[0]
    }

    pub fn reshape(mut self, shape: &[usize]) -> Result<Self> {
        if shape.iter().product::<usize>() != self.data.len() {
            bail!("reshape {:?} -> {:?}: element count mismatch", self.shape, shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// Row `i` of a 2-D tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.ndim(), 2);
        let w = self.shape[1];
        &self.data[i * w..(i + 1) * w]
    }

    /// 2-D indexing.
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.ndim(), 2);
        self.data[i * self.shape[1] + j]
    }

    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        debug_assert_eq!(self.ndim(), 2);
        let w = self.shape[1];
        self.data[i * w + j] = v;
    }

    /// Slice along the leading axis: `self[i]` with one fewer dim.
    pub fn index0(&self, i: usize) -> Tensor {
        assert!(self.ndim() >= 1 && i < self.shape[0]);
        let inner: usize = self.shape[1..].iter().product();
        Tensor::new(self.shape[1..].to_vec(),
                    self.data[i * inner..(i + 1) * inner].to_vec())
    }

    /// Write `src` into `self[i]` along the leading axis.
    pub fn set_index0(&mut self, i: usize, src: &Tensor) {
        let inner: usize = self.shape[1..].iter().product();
        assert_eq!(src.shape(), &self.shape[1..]);
        self.data[i * inner..(i + 1) * inner].copy_from_slice(src.data());
    }

    /// Stack tensors of identical shape along a new leading axis.
    pub fn stack(parts: &[Tensor]) -> Result<Tensor> {
        if parts.is_empty() {
            bail!("stack of zero tensors");
        }
        let inner_shape = parts[0].shape().to_vec();
        let mut data = Vec::with_capacity(parts.len() * parts[0].len());
        for p in parts {
            if p.shape() != inner_shape.as_slice() {
                bail!("stack shape mismatch {:?} vs {:?}", p.shape(), inner_shape);
            }
            data.extend_from_slice(p.data());
        }
        let mut shape = vec![parts.len()];
        shape.extend_from_slice(&inner_shape);
        Ok(Tensor::new(shape, data))
    }

    /// 2-D transpose.
    pub fn transpose2(&self) -> Tensor {
        assert_eq!(self.ndim(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                out[j * m + i] = self.data[i * n + j];
            }
        }
        Tensor::new(vec![n, m], out)
    }

    /// Column slice of a 2-D tensor: columns [lo, hi).
    pub fn cols(&self, lo: usize, hi: usize) -> Tensor {
        assert_eq!(self.ndim(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        assert!(lo <= hi && hi <= n);
        let w = hi - lo;
        let mut out = Vec::with_capacity(m * w);
        for i in 0..m {
            out.extend_from_slice(&self.data[i * n + lo..i * n + hi]);
        }
        Tensor::new(vec![m, w], out)
    }

    /// Row slice of a 2-D tensor: rows [lo, hi).
    pub fn rows(&self, lo: usize, hi: usize) -> Tensor {
        assert_eq!(self.ndim(), 2);
        let n = self.shape[1];
        assert!(lo <= hi && hi <= self.shape[0]);
        Tensor::new(vec![hi - lo, n], self.data[lo * n..hi * n].to_vec())
    }

    /// Frobenius / L2 norm.
    pub fn norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    /// L2 norm of column j (2-D).
    pub fn col_norm(&self, j: usize) -> f32 {
        assert_eq!(self.ndim(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        (0..m).map(|i| {
            let v = self.data[i * n + j];
            v * v
        }).sum::<f32>().sqrt()
    }

    pub fn scale(&mut self, s: f32) {
        for v in &mut self.data {
            *v *= s;
        }
    }

    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(other.data.iter()) {
            *a += b;
        }
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.shape, other.shape);
        let data = self.data.iter().zip(other.data.iter()).map(|(a, b)| a - b).collect();
        Tensor::new(self.shape.clone(), data)
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data.iter().zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// Row-major i32 tensor (token ids, positions).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorI {
    shape: Vec<usize>,
    data: Vec<i32>,
}

impl TensorI {
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self { shape, data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Self { shape: shape.to_vec(), data: vec![0; shape.iter().product()] }
    }

    pub fn scalar(v: i32) -> Self {
        Self { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn data(&self) -> &[i32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [i32] {
        &mut self.data
    }

    pub fn item(&self) -> i32 {
        assert_eq!(self.data.len(), 1);
        self.data[0]
    }
}

/// A tensor of either dtype — what a program argument actually is.
#[derive(Clone, Debug)]
pub enum Value {
    F32(Tensor),
    I32(TensorI),
}

impl Value {
    pub fn shape(&self) -> &[usize] {
        match self {
            Value::F32(t) => t.shape(),
            Value::I32(t) => t.shape(),
        }
    }

    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            Value::I32(_) => bail!("expected f32 tensor, got i32"),
        }
    }

    pub fn as_i32(&self) -> Result<&TensorI> {
        match self {
            Value::I32(t) => Ok(t),
            Value::F32(_) => bail!("expected i32 tensor, got f32"),
        }
    }

    pub fn into_f32(self) -> Result<Tensor> {
        match self {
            Value::F32(t) => Ok(t),
            Value::I32(_) => bail!("expected f32 tensor, got i32"),
        }
    }
}

impl From<Tensor> for Value {
    fn from(t: Tensor) -> Self {
        Value::F32(t)
    }
}

impl From<TensorI> for Value {
    fn from(t: TensorI) -> Self {
        Value::I32(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reshape_and_index() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.at2(1, 2), 6.0);
        assert_eq!(t.row(0), &[1., 2., 3.]);
        let r = t.clone().reshape(&[3, 2]).unwrap();
        assert_eq!(r.at2(2, 1), 6.0);
        assert!(t.clone().reshape(&[4, 2]).is_err());
    }

    #[test]
    fn transpose_roundtrip() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let tt = t.transpose2().transpose2();
        assert_eq!(t, tt);
    }

    #[test]
    fn cols_rows_slices() {
        let t = Tensor::new(vec![2, 4], (0..8).map(|x| x as f32).collect());
        let c = t.cols(1, 3);
        assert_eq!(c.shape(), &[2, 2]);
        assert_eq!(c.data(), &[1., 2., 5., 6.]);
        let r = t.rows(1, 2);
        assert_eq!(r.data(), &[4., 5., 6., 7.]);
    }

    #[test]
    fn stack_and_index0() {
        let a = Tensor::new(vec![2], vec![1., 2.]);
        let b = Tensor::new(vec![2], vec![3., 4.]);
        let s = Tensor::stack(&[a.clone(), b.clone()]).unwrap();
        assert_eq!(s.shape(), &[2, 2]);
        assert_eq!(s.index0(1), b);
        let mut s2 = s.clone();
        s2.set_index0(0, &b);
        assert_eq!(s2.index0(0), b);
    }

    #[test]
    fn norms() {
        let t = Tensor::new(vec![2, 2], vec![3., 0., 4., 0.]);
        assert!((t.norm() - 5.0).abs() < 1e-6);
        assert!((t.col_norm(0) - 5.0).abs() < 1e-6);
        assert_eq!(t.col_norm(1), 0.0);
    }

    #[test]
    fn eye_identity() {
        let i = Tensor::eye(3);
        assert_eq!(i.at2(0, 0), 1.0);
        assert_eq!(i.at2(0, 1), 0.0);
        assert_eq!(i.len(), 9);
    }
}
