//! Generic training driver over any AOT train-step program.
//!
//! Every train-step artifact follows the same calling convention (see
//! `python/compile/aot.py`):
//!
//! ```text
//! inputs : [param tensors…] [m_<t>…] [v_<t>…] step batch… lr
//! outputs: [updated trainable tensors…] [m_<t>…] [v_<t>…] step loss
//! ```
//!
//! The trainer resolves input names against a stack of [`ParamSet`]
//! providers (base params, adapters, …) plus per-step batch values, runs
//! the executable, and writes updated tensors back by name — so dense
//! pretraining, factorized recovery, CLOVER-S fine-tuning, and all PEFT
//! baselines share this one loop.

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

use crate::model::params::ParamSet;
use crate::runtime::Runtime;
use crate::tensor::{Tensor, TensorI, Value};
use crate::util::Stopwatch;

use super::schedule::lr_at;

/// Mutable training state: parameter providers + optimizer moments.
pub struct TrainState {
    /// Providers searched in order for plain-named tensors.  Updated
    /// tensors are written back to whichever provider owns the name.
    pub sets: Vec<ParamSet>,
    pub m: BTreeMap<String, Tensor>,
    pub v: BTreeMap<String, Tensor>,
    pub step: i32,
}

impl TrainState {
    pub fn new(sets: Vec<ParamSet>) -> Self {
        Self { sets, m: BTreeMap::new(), v: BTreeMap::new(), step: 0 }
    }

    fn lookup(&self, name: &str) -> Option<&Tensor> {
        self.sets.iter().find_map(|s| s.get(name).ok())
    }

    fn write_back(&mut self, name: &str, t: Tensor) -> Result<()> {
        for s in &mut self.sets {
            if s.get(name).is_ok() {
                return s.set(name, t);
            }
        }
        bail!("updated tensor {name:?} has no owning provider")
    }

    /// First provider (by convention the primary parameter set).
    pub fn primary(&self) -> &ParamSet {
        &self.sets[0]
    }
}

/// One optimizer step of `config/program`.  `batch` supplies the
/// non-parameter inputs by name (e.g. "inputs"/"targets" or
/// "feats"/"tokens_in"/"tokens_tgt").  Returns the loss.
pub fn train_step(
    rt: &Runtime,
    config: &str,
    program: &str,
    state: &mut TrainState,
    batch: &BTreeMap<String, Value>,
    lr: f64,
) -> Result<f32> {
    let sig = rt.manifest().config(config)?.program(program)?.clone();
    let mut args: Vec<Value> = Vec::with_capacity(sig.inputs.len());
    for spec in &sig.inputs {
        let name = spec.name.as_str();
        let val: Value = if name == "step" {
            Value::I32(TensorI::scalar(state.step))
        } else if name == "lr" {
            Value::F32(Tensor::scalar(lr as f32))
        } else if let Some(v) = batch.get(name) {
            v.clone()
        } else if let Some(rest) = name.strip_prefix("m_") {
            let t = state.m.entry(rest.to_string())
                .or_insert_with(|| Tensor::zeros(&spec.shape));
            Value::F32(t.clone())
        } else if let Some(rest) = name.strip_prefix("v_") {
            let t = state.v.entry(rest.to_string())
                .or_insert_with(|| Tensor::zeros(&spec.shape));
            Value::F32(t.clone())
        } else if let Some(t) = state.lookup(name) {
            Value::F32(t.clone())
        } else {
            bail!("{config}/{program}: no provider for input {name:?}");
        };
        args.push(val);
    }

    let outs = rt.run(config, program, &args)?;
    let mut loss = f32::NAN;
    for (spec, out) in sig.outputs.iter().zip(outs) {
        let name = spec.name.as_str();
        if name == "loss" {
            loss = out.as_f32()?.item();
        } else if name == "step" {
            state.step = out.as_i32()?.item();
        } else if let Some(rest) = name.strip_prefix("m_") {
            state.m.insert(rest.to_string(), out.into_f32()?);
        } else if let Some(rest) = name.strip_prefix("v_") {
            state.v.insert(rest.to_string(), out.into_f32()?);
        } else {
            state.write_back(name, out.into_f32()?)
                .with_context(|| format!("{config}/{program} output {name}"))?;
        }
    }
    if loss.is_nan() {
        bail!("{config}/{program}: program emitted no loss");
    }
    Ok(loss)
}

/// Training-loop options.
pub struct LoopOpts {
    pub steps: usize,
    pub lr: f64,
    pub schedule: String,
    pub warmup: usize,
    pub log_every: usize,
    pub tag: String,
}

/// Run a full training loop, pulling batches from `next_batch`.
/// Returns the logged (step, loss) curve.
pub fn train_loop<F>(
    rt: &Runtime,
    config: &str,
    program: &str,
    state: &mut TrainState,
    opts: &LoopOpts,
    mut next_batch: F,
) -> Result<Vec<(usize, f32)>>
where
    F: FnMut(usize) -> BTreeMap<String, Value>,
{
    let sw = Stopwatch::new();
    let mut curve = Vec::new();
    let mut ema: Option<f32> = None;
    for i in 0..opts.steps {
        let lr = lr_at(&opts.schedule, opts.lr, i, opts.steps, opts.warmup);
        let batch = next_batch(i);
        let loss = train_step(rt, config, program, state, &batch, lr)?;
        ema = Some(match ema {
            None => loss,
            Some(e) => 0.95 * e + 0.05 * loss,
        });
        if opts.log_every > 0 && (i % opts.log_every == 0 || i + 1 == opts.steps) {
            crate::info!(
                "[{}] step {:>5}/{} loss {:.4} (ema {:.4}) lr {:.2e} [{:.0}s]",
                opts.tag, i + 1, opts.steps, loss, ema.unwrap(), lr, sw.elapsed_s()
            );
            curve.push((i, ema.unwrap()));
        }
    }
    Ok(curve)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::params::ParamSet;
    use crate::runtime::Runtime;
    use crate::util::rng::Rng;

    fn art() -> String {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    }

    fn init_tiny(rt: &Runtime) -> ParamSet {
        let tiny = rt.manifest().config("tiny").unwrap().clone();
        let outs = rt.run("tiny", "init", &[Value::I32(TensorI::scalar(7))]).unwrap();
        let tensors: Vec<Tensor> = outs.into_iter().map(|v| v.into_f32().unwrap()).collect();
        ParamSet::from_flat(&tiny.params_dense, tensors).unwrap()
    }

    #[test]
    fn full_training_reduces_loss() {
        let Some(rt) = crate::testing::runtime_or_skip(&art()) else { return };
        let params = init_tiny(&rt);
        let mut state = TrainState::new(vec![params]);
        let tiny = rt.manifest().config("tiny").unwrap().clone();
        let (b, t) = (tiny.dim("train_batch").unwrap(), tiny.dim("seq_len").unwrap());
        let (_, stream) = crate::data::build_lm_stream("mixture", 256, 60_000, 5);
        let mut rng = Rng::new(0);
        let opts = LoopOpts {
            steps: 8, lr: 3e-3, schedule: "constant".into(),
            warmup: 0, log_every: 0, tag: "test".into(),
        };
        let mut first = None;
        let mut last = 0.0;
        for i in 0..opts.steps {
            let (inp, tgt) = stream.train_batch(&mut rng, b, t);
            let mut batch = BTreeMap::new();
            batch.insert("inputs".into(), Value::I32(inp));
            batch.insert("targets".into(), Value::I32(tgt));
            last = train_step(&rt, "tiny", "train_full", &mut state, &batch, 3e-3).unwrap();
            if i == 0 {
                first = Some(last);
            }
        }
        assert_eq!(state.step, 8);
        assert!(last < first.unwrap(), "loss {first:?} -> {last}");
    }

    #[test]
    fn unknown_input_is_error() {
        let Some(rt) = crate::testing::runtime_or_skip(&art()) else { return };
        let mut state = TrainState::new(vec![ParamSet::zeros(&vec![])]);
        let batch = BTreeMap::new();
        let r = train_step(&rt, "tiny", "train_full", &mut state, &batch, 1e-3);
        assert!(r.is_err());
    }
}
