//! Evaluation: perplexity over held-out token streams and multiple-choice
//! task accuracy via option log-likelihood scoring.

use anyhow::{bail, Result};

use crate::data::batch::TokenStream;
use crate::data::tasks::Example;
use crate::data::tokenizer::Tokenizer;
use crate::model::params::ParamSet;
use crate::runtime::Runtime;
use crate::tensor::{TensorI, Value};

/// Mean NLL over deterministic sequential validation batches → perplexity.
pub fn perplexity(
    rt: &Runtime,
    config: &str,
    program: &str,
    params: &ParamSet,
    stream: &TokenStream,
    max_batches: usize,
) -> Result<f64> {
    let entry = rt.manifest().config(config)?;
    let b = entry.dim("train_batch")?;
    let t = entry.dim("seq_len")?;
    let batches = stream.valid_batches_seq(b, t, max_batches);
    if batches.is_empty() {
        bail!("validation stream too short for a single batch");
    }
    let mut total = 0.0f64;
    for (inp, tgt) in &batches {
        let mut args: Vec<Value> = params.flat().iter().map(|&t| Value::F32(t.clone())).collect();
        args.push(Value::I32(inp.clone()));
        args.push(Value::I32(tgt.clone()));
        total += rt.run_scalar(config, program, &args, 0)? as f64;
    }
    Ok((total / batches.len() as f64).exp())
}

/// Log-softmax-based sequence scoring from raw logits.
///
/// `logits` [B,T,V] row-major; returns per-row sum of log P(target_t)
/// restricted to positions `[lo_t, hi_t)` (the answer span).
fn score_rows(
    logits: &[f32],
    b: usize,
    t: usize,
    v: usize,
    tokens: &[i32],
    spans: &[(usize, usize)],
) -> Vec<f64> {
    let mut scores = vec![0.0f64; b];
    for row in 0..b {
        let (lo, hi) = spans[row];
        for pos in lo..hi.min(t - 1) {
            // predictor at `pos` scores token at pos+1
            let base = (row * t + pos) * v;
            let slice = &logits[base..base + v];
            let maxv = slice.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let logz: f32 = slice.iter().map(|x| (x - maxv).exp()).sum::<f32>().ln() + maxv;
            let tgt = tokens[row * t + pos + 1] as usize;
            scores[row] += (slice[tgt] - logz) as f64;
        }
    }
    scores
}

/// Multiple-choice accuracy on one task: every option of every example is
/// scored by total answer-span log-likelihood under the LM; prediction =
/// argmax option.
pub fn task_accuracy(
    rt: &Runtime,
    config: &str,
    fwd_program: &str,
    extra_param_sets: &[&ParamSet],
    params: &ParamSet,
    tok: &Tokenizer,
    examples: &[Example],
) -> Result<f64> {
    let entry = rt.manifest().config(config)?;
    let b = entry.dim("train_batch")?;
    let t = entry.dim("seq_len")?;
    let v = entry.dim("vocab")?;

    // Flatten (example, option) pairs into batches.
    struct Cand {
        example: usize,
        option: usize,
        tokens: Vec<i32>,
        span: (usize, usize),
    }
    let mut cands = Vec::new();
    for (ei, ex) in examples.iter().enumerate() {
        let prompt_ids = tok.encode(&format!("{} answer:", ex.prompt));
        for (oi, _) in ex.options.iter().enumerate() {
            let ids = tok.encode(&ex.option_text(oi));
            let mut padded = vec![0i32; t];
            let n = ids.len().min(t);
            padded[..n].copy_from_slice(&ids[..n]);
            // answer span: from end of prompt to end of candidate
            let lo = prompt_ids.len().saturating_sub(1).min(t - 1);
            let hi = n.saturating_sub(1).max(lo);
            cands.push(Cand { example: ei, option: oi, tokens: padded, span: (lo, hi) });
        }
    }

    let mut option_scores: Vec<Vec<f64>> =
        examples.iter().map(|e| vec![f64::NEG_INFINITY; e.options.len()]).collect();

    for chunk in cands.chunks(b) {
        let mut tokens = vec![0i32; b * t];
        let mut spans = vec![(0usize, 0usize); b];
        for (i, c) in chunk.iter().enumerate() {
            tokens[i * t..(i + 1) * t].copy_from_slice(&c.tokens);
            spans[i] = c.span;
        }
        let mut args: Vec<Value> = params.flat().iter().map(|&x| Value::F32(x.clone())).collect();
        for set in extra_param_sets {
            args.extend(set.flat().iter().map(|&x| Value::F32(x.clone())));
        }
        args.push(Value::I32(TensorI::new(vec![b, t], tokens.clone())));
        let outs = rt.run(config, fwd_program, &args)?;
        let logits = outs[0].as_f32()?;
        let scores = score_rows(logits.data(), b, t, v, &tokens, &spans);
        for (i, c) in chunk.iter().enumerate() {
            option_scores[c.example][c.option] = scores[i];
        }
    }

    let mut correct = 0usize;
    for (ex, scores) in examples.iter().zip(&option_scores) {
        let pred = scores.iter().enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i).unwrap_or(0);
        if pred == ex.gold {
            correct += 1;
        }
    }
    Ok(correct as f64 / examples.len() as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn score_rows_prefers_likely_token() {
        // B=1, T=3, V=2; logits strongly favor token 1 everywhere
        let logits = vec![0.0, 5.0, 0.0, 5.0, 0.0, 5.0];
        let tok_good = vec![1, 1, 1];
        let tok_bad = vec![1, 0, 0];
        let s_good = score_rows(&logits, 1, 3, 2, &tok_good, &[(0, 2)]);
        let s_bad = score_rows(&logits, 1, 3, 2, &tok_bad, &[(0, 2)]);
        assert!(s_good[0] > s_bad[0]);
    }

    #[test]
    fn span_restriction() {
        let logits = vec![0.0, 5.0, 0.0, 5.0, 0.0, 5.0];
        let toks = vec![1, 0, 0];
        let full = score_rows(&logits, 1, 3, 2, &toks, &[(0, 2)]);
        let tail = score_rows(&logits, 1, 3, 2, &toks, &[(1, 2)]);
        assert!(tail[0] > full[0]); // skipping the first bad position helps
    }
}
