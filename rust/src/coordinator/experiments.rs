//! Experiment runners — one per paper table/figure (DESIGN.md §4 index).
//!
//! Each runner is a plain function over a [`Runtime`] so the CLI
//! (`clover report <id>`), the benches (`cargo bench --bench table1_...`),
//! and the examples all share one implementation.  `quick: true` shrinks
//! step budgets ~4× for smoke runs; EXPERIMENTS.md records full runs.
//!
//! Scale note: the paper's models (GPT-2-XL, LLaMA-7B, Whisper-large) are
//! re-staged as the `tiny` preset trained from scratch on synthetic data
//! (substitution table in DESIGN.md §2); reproduction targets are the
//! *shapes* of each result, not absolute numbers.

use anyhow::{Context, Result};
use std::collections::BTreeMap;

use crate::clover;
use crate::data::{self, all_tasks, SignalRenderer, TokenStream, Tokenizer};
use crate::model::params::ParamSet;
use crate::model::{load_params, save_params, Checkpoint};
use crate::peft;
use crate::report::Table;
use crate::runtime::Runtime;
use crate::tensor::{Tensor, TensorI, Value};
use crate::util::rng::Rng;

use super::eval::{perplexity, task_accuracy};
use super::ops::{self, lm_batcher};
use super::trainer::{train_loop, LoopOpts, TrainState};

/// Common experiment options.
#[derive(Clone, Debug)]
pub struct ExpOpts {
    pub preset: String,
    pub quick: bool,
    pub seed: u64,
}

impl Default for ExpOpts {
    fn default() -> Self {
        Self { preset: "tiny".into(), quick: false, seed: 42 }
    }
}

fn scale(opts: &ExpOpts, full: usize) -> usize {
    if opts.quick { (full / 4).max(4) } else { full }
}

/// Pretrain (or load a cached) base model for a preset.  The checkpoint is
/// cached under `runs/` keyed by preset/steps/seed so every experiment
/// shares one pretrained base.
pub fn base_model(
    rt: &Runtime,
    opts: &ExpOpts,
    steps: usize,
) -> Result<(ParamSet, Tokenizer, TokenStream)> {
    let entry = rt.manifest().config(&opts.preset)?.clone();
    let vocab = entry.dim("vocab")?;
    let (tok, stream) = data::build_lm_stream("mixture", vocab, 400_000, opts.seed);
    let path = std::path::PathBuf::from("runs").join(format!(
        "base_{}_{}steps_seed{}.clvr", opts.preset, steps, opts.seed
    ));
    if path.exists() {
        let ck = Checkpoint::load(&path)?;
        let params = load_params(&ck, &entry.params_dense)?;
        crate::info!("loaded cached base model {path:?}");
        return Ok((params, tok, stream));
    }
    let init = ops::init_params(rt, &opts.preset, opts.seed as i32)?;
    let (params, _curve) = ops::pretrain(rt, &opts.preset, init, &stream, &ops::PretrainOpts {
        steps, lr: 1e-3, seed: opts.seed, tag: "pretrain".into(),
    })?;
    save_params(&params, &opts.preset, "dense", steps, &path)?;
    Ok((params, tok, stream))
}

// ---------------------------------------------------------------------
// Table 1: pruning ratio sweep — Vanilla vs CLOVER vs CLOVER†
// ---------------------------------------------------------------------

pub fn table1(rt: &Runtime, opts: &ExpOpts) -> Result<Table> {
    let entry = rt.manifest().config(&opts.preset)?.clone();
    let (b, t) = (entry.dim("train_batch")?, entry.dim("seq_len")?);
    let pre_steps = scale(opts, 600);
    let (dense, _tok, stream) = base_model(rt, opts, pre_steps)?;
    let base_ppl = perplexity(rt, &opts.preset, "nll", &dense, &stream, 8)?;
    crate::info!("base model ppl {base_ppl:.2}");

    // Two token budgets (the paper's 66M / 131M, scaled): steps × B × T.
    let budget1 = scale(opts, 120);
    let budget2 = scale(opts, 240);
    let ratios = if opts.quick {
        vec![0.25, 0.5, 0.75]
    } else {
        vec![0.125, 0.25, 0.375, 0.5, 0.625, 0.75]
    };

    let mut table = Table::new(
        &format!(
            "Table 1 — pruning {} (base ppl {:.2}; budgets {}k / {}k tokens)",
            opts.preset, base_ppl,
            budget1 * b * t / 1000, budget2 * b * t / 1000
        ),
        &["ratio", "van_ppl", "clv_ppl",
          "van_ft1", "clv_ft1", "clv†_ft1",
          "van_ft2", "clv_ft2", "clv†_ft2"],
    );

    for ratio in ratios {
        let (van, r) = ops::prune_to_ratio(&entry, &dense, ratio, "vanilla")?;
        let (clv, r2) = ops::prune_to_ratio(&entry, &dense, ratio, "clover")?;
        assert_eq!(r, r2);
        let van_ppl = ops::fac_perplexity(rt, &opts.preset, &van, r, &stream, 8)?;
        let clv_ppl = ops::fac_perplexity(rt, &opts.preset, &clv, r, &stream, 8)?;
        let mut cells = vec![
            format!("{:.1}%", ratio * 100.0),
            format!("{van_ppl:.2}"),
            format!("{clv_ppl:.2}"),
        ];
        for budget in [budget1, budget2] {
            let ropts = |mode: &str, lr: f64| ops::RecoverOpts {
                r, mode: mode.into(), steps: budget, lr, seed: opts.seed,
            };
            // Vanilla recovery: fine-tune factorized attention tensors.
            let (van_ft, _) =
                ops::recover(rt, &opts.preset, van.clone(), &stream, &ropts("attn", 2e-4))?;
            let (clv_ft, _) =
                ops::recover(rt, &opts.preset, clv.clone(), &stream, &ropts("attn", 2e-4))?;
            // CLOVER†: fine-tune only the singular values, 10x lr (paper
            // bumps 6e-4 -> 6e-3 for the S-only run).
            let (clv_s, _) =
                ops::recover(rt, &opts.preset, clv.clone(), &stream, &ropts("s", 6e-3))?;
            cells.push(format!(
                "{:.2}", ops::fac_perplexity(rt, &opts.preset, &van_ft, r, &stream, 8)?
            ));
            cells.push(format!(
                "{:.2}", ops::fac_perplexity(rt, &opts.preset, &clv_ft, r, &stream, 8)?
            ));
            cells.push(format!(
                "{:.2}", ops::fac_perplexity(rt, &opts.preset, &clv_s, r, &stream, 8)?
            ));
        }
        table.row(cells);
    }
    Ok(table)
}

// ---------------------------------------------------------------------
// Figure 1c: perplexity vs pruning rank (no fine-tuning)
// ---------------------------------------------------------------------

pub fn fig1c(rt: &Runtime, opts: &ExpOpts) -> Result<Table> {
    let entry = rt.manifest().config(&opts.preset)?.clone();
    let (dense, _tok, stream) = base_model(rt, opts, scale(opts, 600))?;
    let mut table = Table::new(
        "Fig 1c — ppl vs pruned vectors (no fine-tuning)",
        &["rank", "pruned_dirs", "vanilla_ppl", "clover_ppl"],
    );
    let dh = entry.dim("d_head")?;
    for &r in &entry.ranks {
        let (van, _) = ops::prune_to_ratio(&entry, &dense, clover::achieved_ratio(dh, r), "vanilla")?;
        let (clv, _) = ops::prune_to_ratio(&entry, &dense, clover::achieved_ratio(dh, r), "clover")?;
        table.row(vec![
            r.to_string(),
            (dh - r).to_string(),
            format!("{:.2}", ops::fac_perplexity(rt, &opts.preset, &van, r, &stream, 8)?),
            format!("{:.2}", ops::fac_perplexity(rt, &opts.preset, &clv, r, &stream, 8)?),
        ]);
    }
    Ok(table)
}

// ---------------------------------------------------------------------
// Figure 1d: recovery fine-tuning — S-only vs full attention
// ---------------------------------------------------------------------

pub fn fig1d(rt: &Runtime, opts: &ExpOpts) -> Result<Table> {
    let entry = rt.manifest().config(&opts.preset)?.clone();
    let (dense, _tok, stream) = base_model(rt, opts, scale(opts, 600))?;
    let (clv, r) = ops::prune_to_ratio(&entry, &dense, 0.5, "clover")?;
    let steps = scale(opts, 160);
    let mut table = Table::new(
        "Fig 1d — recovery FT at 50% pruning: trainable params vs ppl",
        &["mode", "trainable", "ppl_before", "ppl_after"],
    );
    let before = ops::fac_perplexity(rt, &opts.preset, &clv, r, &stream, 8)?;
    for (mode, lr) in [("attn", 2e-4), ("s", 2e-3)] {
        let (ft, _) = ops::recover(rt, &opts.preset, clv.clone(), &stream, &ops::RecoverOpts {
            r, mode: mode.into(), steps, lr, seed: opts.seed,
        })?;
        let after = ops::fac_perplexity(rt, &opts.preset, &ft, r, &stream, 8)?;
        let spec = entry.params_fac.get(&r).unwrap();
        let trainable: usize = spec.iter()
            .filter(|(n, _)| if mode == "s" {
                n.starts_with("s_")
            } else {
                n.starts_with("u_") || n.starts_with("s_") || n.starts_with("v_")
            })
            .map(|(_, s)| s.iter().product::<usize>())
            .sum();
        table.row(vec![
            mode.into(), trainable.to_string(),
            format!("{before:.2}"), format!("{after:.2}"),
        ]);
    }
    Ok(table)
}

// ---------------------------------------------------------------------
// Figure 2 (+7/8): per-head importance spectra
// ---------------------------------------------------------------------

pub fn fig2(rt: &Runtime, opts: &ExpOpts, all_layers: bool) -> Result<Table> {
    let entry = rt.manifest().config(&opts.preset)?.clone();
    let h = entry.dim("n_heads")?;
    let dh = entry.dim("d_head")?;
    let (dense, _tok, _stream) = base_model(rt, opts, scale(opts, 600))?;
    let fac_spec = entry.params_fac.get(&dh).context("full-rank spec")?;
    let (_, spectra) = clover::clover_transform(&dense, fac_spec, h, &clover::DECODER_NAMING)?;

    let wq = dense.get("wq")?;
    let wk = dense.get("wk")?;
    let mut table = Table::new(
        "Fig 2 — Q-K head importance: CLOVER singular values vs vanilla norms",
        &["layer", "head", "dim", "clover_sv", "vanilla_norm"],
    );
    let layers: Vec<usize> = if all_layers {
        (0..spectra.qk.len()).collect()
    } else {
        vec![0]
    };
    for l in layers {
        let heads: Vec<usize> = if all_layers { (0..h).collect() } else { vec![0] };
        for hi in heads {
            let wq_l = wq.index0(l);
            let wk_l = wk.index0(l);
            let q_h = wq_l.cols(hi * dh, (hi + 1) * dh);
            let k_h = wk_l.cols(hi * dh, (hi + 1) * dh);
            let mut vn = clover::vanilla::importance_qk(&q_h, &k_h);
            vn.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let sv = &spectra.qk[l][hi];
            for i in 0..dh {
                table.row(vec![
                    l.to_string(), hi.to_string(), i.to_string(),
                    format!("{:.4}", sv[i]), format!("{:.4}", vn[i]),
                ]);
            }
        }
    }
    Ok(table)
}

// ---------------------------------------------------------------------
// Figure 3 / §4.4: whisper-like training-free pruning
// ---------------------------------------------------------------------

pub fn fig3_whisper(rt: &Runtime, opts: &ExpOpts) -> Result<Table> {
    let cfg_name = "s2s_tiny";
    let entry = rt.manifest().config(cfg_name)?.clone();
    let (b, src, tgt) = (entry.dim("batch")?, entry.dim("src_len")?, entry.dim("tgt_len")?);
    let vocab = entry.dim("vocab")?;
    let h = entry.dim("n_heads")?;
    let dh = entry.dim("d_head")?;
    let renderer = SignalRenderer::new(vocab, entry.dim("feat_dim")?, 0.05, opts.seed);

    // Train (or load) the transcription model.
    let steps = scale(opts, 500);
    let path = std::path::PathBuf::from("runs")
        .join(format!("s2s_{steps}steps_seed{}.clvr", opts.seed));
    let params = if path.exists() {
        load_params(&Checkpoint::load(&path)?, &entry.params_dense)?
    } else {
        let init = ops::init_params(rt, cfg_name, opts.seed as i32)?;
        let mut state = TrainState::new(vec![init]);
        let mut rng = Rng::new(opts.seed);
        let lopts = LoopOpts {
            steps, lr: 3e-3, schedule: "cosine".into(), warmup: 20,
            log_every: (steps / 10).max(1), tag: "s2s".into(),
        };
        train_loop(rt, cfg_name, "train_full", &mut state, &lopts, |_| {
            let (feats, dec_in, dec_tgt) = renderer.batch(&mut rng, b, src, tgt);
            let mut m = BTreeMap::new();
            m.insert("feats".to_string(), Value::F32(feats));
            m.insert("tokens_in".to_string(), Value::I32(TensorI::new(vec![b, tgt], dec_in)));
            m.insert("tokens_tgt".to_string(), Value::I32(TensorI::new(vec![b, tgt], dec_tgt)));
            m
        })?;
        let p = state.sets.remove(0);
        save_params(&p, cfg_name, "s2s", steps, &path)?;
        p
    };

    // Teacher-forced token error rate under a given forward program.
    let ter_of = |params: &ParamSet, program: &str, eval_seed: u64| -> Result<f64> {
        let mut rng = Rng::new(eval_seed);
        let mut total = 0.0;
        let n_batches = 4;
        for _ in 0..n_batches {
            let (feats, dec_in, dec_tgt) = renderer.batch(&mut rng, b, src, tgt);
            let mut args: Vec<Value> =
                params.flat().iter().map(|&t| Value::F32(t.clone())).collect();
            args.push(Value::F32(feats));
            args.push(Value::I32(TensorI::new(vec![b, tgt], dec_in)));
            let outs = rt.run(cfg_name, program, &args)?;
            let logits = outs[0].as_f32()?;
            // argmax per position
            for row in 0..b {
                let mut pred = vec![0i32; tgt];
                for p in 0..tgt {
                    let base = (row * tgt + p) * vocab;
                    let mut best = 0;
                    let mut bv = f32::NEG_INFINITY;
                    for j in 0..vocab {
                        let x = logits.data()[base + j];
                        if x > bv {
                            bv = x;
                            best = j;
                        }
                    }
                    pred[p] = best as i32;
                }
                total += data::signal::token_error_rate(&pred, &dec_tgt[row * tgt..(row + 1) * tgt]);
            }
        }
        Ok(total / (n_batches * b) as f64)
    };

    let base_ter = ter_of(&params, "fwd", opts.seed + 100)?;
    let mut table = Table::new(
        &format!("Fig 3 / §4.4 — whisper-like training-free pruning (base TER {base_ter:.3})"),
        &["method", "rank", "ratio", "TER"],
    );
    // Uniform-rank sweep: CLOVER vs vanilla at the same kept rank.
    for &r in &entry.ranks {
        if r == dh {
            continue;
        }
        let fac_spec = entry.params_fac.get(&r).unwrap();
        let clv = clover::clover_transform(&params, fac_spec, h, &clover::ENCODER_NAMING)?.0;
        let van = clover::vanilla_prune(&params, fac_spec, h, &clover::ENCODER_NAMING)?;
        let ratio = clover::achieved_ratio(dh, r);
        table.row(vec![
            "clover".into(), r.to_string(), format!("{:.1}%", ratio * 100.0),
            format!("{:.3}", ter_of(&clv, &format!("fwd_fac_r{r}"), opts.seed + 100)?),
        ]);
        table.row(vec![
            "vanilla".into(), r.to_string(), format!("{:.1}%", ratio * 100.0),
            format!("{:.3}", ter_of(&van, &format!("fwd_fac_r{r}"), opts.seed + 100)?),
        ]);
    }
    Ok(table)
}

// ---------------------------------------------------------------------
// Figure 4: projection of data features onto adapter directions
// ---------------------------------------------------------------------

pub fn fig4(rt: &Runtime, opts: &ExpOpts) -> Result<Table> {
    let entry = rt.manifest().config(&opts.preset)?.clone();
    let (b, t) = (entry.dim("train_batch")?, entry.dim("seq_len")?);
    let h = entry.dim("n_heads")?;
    let dh = entry.dim("d_head")?;
    let d = entry.dim("d_model")?;
    let (dense, _tok, stream) = base_model(rt, opts, scale(opts, 600))?;

    // Hidden states from the middle layer on a sampled batch.
    let mut rng = Rng::new(opts.seed + 7);
    let (inp, _) = stream.valid_batch(&mut rng, b, t);
    let mut args: Vec<Value> = dense.flat().iter().map(|&x| Value::F32(x.clone())).collect();
    args.push(Value::I32(inp));
    let outs = rt.run(&opts.preset, "hidden", &args)?;
    let hidden = outs[0].as_f32()?; // [B, L, T, D]
    let n_layers = entry.dim("n_layers")?;
    let layer = n_layers / 2;
    // Gather X = [B*T, D] for the chosen layer.
    let mut x = Vec::with_capacity(b * t * d);
    for bi in 0..b {
        for ti in 0..t {
            let base = ((bi * n_layers + layer) * t + ti) * d;
            x.extend_from_slice(&hidden.data()[base..base + d]);
        }
    }
    let x = Tensor::new(vec![b * t, d], x);

    // Factorize the middle layer's first head.
    let fac_spec = entry.params_fac.get(&dh).unwrap();
    let (fac, spectra) = clover::clover_transform(&dense, fac_spec, h, &clover::DECODER_NAMING)?;
    let u = fac.get("u_qk")?;
    let head_u = {
        let base = (layer * h) * d * dh;
        Tensor::new(vec![d, dh], {
            let mut out = vec![0.0; d * dh];
            out.copy_from_slice(&u.data()[base..base + d * dh]);
            out
        })
    };
    let s = &spectra.qk[layer][0];
    let r_adapter = (dh / 4).max(1); // the LoRA/PiSSA comparison rank
    let shares = clover::projection_shares(&x, &head_u, s, r_adapter, &mut rng);

    let mut table = Table::new(
        &format!("Fig 4 — feature projection shares (layer {layer}, head 0, r={r_adapter})"),
        &["quantity", "share"],
    );
    table.row(vec![format!("LoRA (random r={r_adapter})"), format!("{:.3}", shares.lora_r)]);
    table.row(vec![format!("PiSSA (top r={r_adapter})"), format!("{:.3}", shares.pissa_r)]);
    table.row(vec!["CLOVER (all dirs)".into(), format!("{:.3}", shares.clover_all)]);
    table.row(vec!["top-1 dir (unscaled)".into(), format!("{:.3}", shares.top1_unscaled)]);
    table.row(vec!["top-1 dir (S-scaled)".into(), format!("{:.3}", shares.top1_scaled)]);
    Ok(table)
}

// ---------------------------------------------------------------------
// Table 2 + Figures 5/6: PEFT comparison, ΔW rank, intruder dimensions
// ---------------------------------------------------------------------

pub struct PeftOutcome {
    pub method: String,
    pub trainable: usize,
    pub accuracy: Vec<(String, f64)>,
    pub avg: f64,
    /// (ΔW singular values, intruder count) on a probe matrix, for Figs 5/6.
    pub delta_s: Vec<f32>,
    pub intruders: usize,
}

/// Fine-tune with every PEFT method on the 8-task suite and evaluate.
pub fn table2(rt: &Runtime, opts: &ExpOpts) -> Result<(Table, Vec<PeftOutcome>)> {
    let entry = rt.manifest().config(&opts.preset)?.clone();
    let (b, t) = (entry.dim("train_batch")?, entry.dim("seq_len")?);
    let h = entry.dim("n_heads")?;
    let (dense, tok, _stream) = base_model(rt, opts, scale(opts, 600))?;

    // Task mixture: concatenated train texts -> token stream.
    let tasks = all_tasks(opts.seed, if opts.quick { 1 } else { 2 });
    let mut train_text = String::new();
    let mut rng = Rng::new(opts.seed + 3);
    let mut examples: Vec<&data::tasks::Example> =
        tasks.iter().flat_map(|t| t.train.iter()).collect();
    rng.shuffle(&mut examples);
    for e in examples {
        train_text.push_str(&e.train_text());
    }
    let ids = tok.encode(&train_text);
    let task_stream = TokenStream::new(ids, 0.02);
    let steps = scale(opts, 300);

    let probe_layer = entry.dim("n_layers")? / 2;
    let probe = |w: &ParamSet| -> Result<Tensor> {
        Ok(w.get("wk")?.index0(probe_layer))
    };
    let w_before = probe(&dense)?;

    let mut outcomes: Vec<PeftOutcome> = Vec::new();

    // ---- zero-shot base ------------------------------------------------
    {
        let mut acc = Vec::new();
        for task in &tasks {
            acc.push((task.name.to_string(),
                      task_accuracy(rt, &opts.preset, "fwd", &[], &dense, &tok, &task.test)?));
        }
        let avg = acc.iter().map(|(_, a)| a).sum::<f64>() / acc.len() as f64;
        outcomes.push(PeftOutcome {
            method: "base (zero-shot)".into(), trainable: 0,
            accuracy: acc, avg, delta_s: vec![], intruders: 0,
        });
    }

    // ---- adapter methods ----------------------------------------------
    let rank = entry.dim("lora_rank")?;
    for method in ["lora", "pissa", "dora", "hira", "cloverft", "full"] {
        crate::info!("table2: fine-tuning {method} ({steps} steps)");
        let mut rng = Rng::new(opts.seed + 11);
        let (program, fwd_prog, mut state, lr): (String, String, TrainState, f64) = match method {
            "lora" => {
                let ad = peft::lora_init(&entry.params_lora, &mut rng);
                (
                    "train_lora".into(), "fwd_lora".into(),
                    TrainState::new(vec![dense.clone(), ad]), 3e-3,
                )
            }
            "pissa" => {
                let (base2, ad) = peft::pissa_init(&dense, &entry.params_lora, rank)?;
                (
                    "train_lora".into(), "fwd_lora".into(),
                    TrainState::new(vec![base2, ad]), 1e-3,
                )
            }
            "dora" => {
                let ad = peft::dora_init(&entry.params_dora, &dense, &mut rng)?;
                (
                    "train_dora".into(), "fwd_dora".into(),
                    TrainState::new(vec![dense.clone(), ad]), 2e-3,
                )
            }
            "hira" => {
                let ad = peft::hira_init(&entry.params_lora, &mut rng);
                (
                    "train_hira".into(), "fwd_hira".into(),
                    TrainState::new(vec![dense.clone(), ad]), 2e-3,
                )
            }
            "cloverft" => {
                let fac = clover::transform::clover_ft_params(&dense, &entry.params_facud, h)?;
                (
                    "train_cloverft".into(), "fwd_cloverft".into(),
                    TrainState::new(vec![fac]), 1e-3,
                )
            }
            _ => (
                "train_full".into(), "fwd".into(),
                TrainState::new(vec![dense.clone()]), 1e-3,
            ),
        };

        let lopts = LoopOpts {
            steps, lr, schedule: "linear".into(), warmup: steps / 10,
            log_every: (steps / 4).max(1), tag: method.into(),
        };
        train_loop(rt, &opts.preset, &program, &mut state, &lopts,
                   lm_batcher(&task_stream, b, t, opts.seed + 13))?;

        // Evaluation: forward program + its parameter providers.
        let mut acc = Vec::new();
        for task in &tasks {
            let a = match method {
                "cloverft" | "full" => task_accuracy(
                    rt, &opts.preset, &fwd_prog, &[], state.primary(), &tok, &task.test,
                )?,
                _ => task_accuracy(
                    rt, &opts.preset, &fwd_prog, &[&state.sets[1]], &state.sets[0],
                    &tok, &task.test,
                )?,
            };
            acc.push((task.name.to_string(), a));
        }
        let avg = acc.iter().map(|(_, a)| a).sum::<f64>() / acc.len() as f64;

        // ΔW analysis on the probe matrix (Figs 5/6).
        let (delta_s, intruders, trainable) = match method {
            "full" => {
                let w_after = probe(state.primary())?;
                (
                    clover::delta_spectrum(&w_before, &w_after),
                    clover::intruder_count(&w_before, &w_after, 8, 0.7),
                    crate::model::manifest::ConfigEntry::param_count(&entry.params_dense),
                )
            }
            "cloverft" => {
                // Effective W_QK (head 0, probe layer) before vs after S FT.
                let fac = state.primary();
                let u = fac.get("u_qk")?.index0(probe_layer);
                let s = fac.get("s_qk")?.index0(probe_layer);
                let v = fac.get("v_qk")?.index0(probe_layer);
                let after = clover::analysis::effective_w(&u, &s, &v, 0);
                let fac0 = clover::transform::clover_ft_params(&dense, &entry.params_facud, h)?;
                let u0 = fac0.get("u_qk")?.index0(probe_layer);
                let s0 = fac0.get("s_qk")?.index0(probe_layer);
                let v0 = fac0.get("v_qk")?.index0(probe_layer);
                let before = clover::analysis::effective_w(&u0, &s0, &v0, 0);
                let trainable: usize = entry.params_facud.iter()
                    .filter(|(n, _)| n.starts_with("s_"))
                    .map(|(_, sh)| sh.iter().product::<usize>()).sum();
                (
                    clover::delta_spectrum(&before, &after),
                    clover::intruder_count(&before, &after, 8, 0.7),
                    trainable,
                )
            }
            "base (zero-shot)" => unreachable!(),
            _ => {
                // adapter methods: effective W_k = base + Δ
                let spec = if method == "dora" { &entry.params_dora } else { &entry.params_lora };
                let trainable = crate::model::manifest::ConfigEntry::param_count(spec);
                let ad = &state.sets[1];
                let a = ad.get("a_k")?.index0(probe_layer);
                let bb = ad.get("b_k")?.index0(probe_layer);
                let delta = crate::linalg::matmul(&a, &bb);
                let mut w_after = probe(&state.sets[0])?;
                if method == "hira" {
                    // ΔW = W ⊙ AB
                    let mut d2 = w_before.clone();
                    for (x, y) in d2.data_mut().iter_mut().zip(delta.data()) {
                        *x *= y;
                    }
                    w_after = w_before.clone();
                    w_after.add_assign(&d2);
                } else {
                    w_after.add_assign(&delta);
                }
                (
                    clover::delta_spectrum(&w_before, &w_after),
                    clover::intruder_count(&w_before, &w_after, 8, 0.7),
                    trainable,
                )
            }
        };

        outcomes.push(PeftOutcome {
            method: method.into(), trainable, accuracy: acc, avg, delta_s, intruders,
        });
    }

    // Render Table 2.
    let mut headers: Vec<&str> = vec!["method", "params"];
    let names: Vec<String> = tasks.iter().map(|t| t.name.to_string()).collect();
    for n in &names {
        headers.push(n);
    }
    headers.push("avg");
    let total = crate::model::manifest::ConfigEntry::param_count(&entry.params_dense);
    let mut table = Table::new(
        &format!("Table 2 — PEFT on 8 synthetic commonsense tasks ({})", opts.preset),
        &headers,
    );
    for o in &outcomes {
        let mut row = vec![
            o.method.clone(),
            if o.trainable == 0 {
                "-".into()
            } else {
                format!("{:.2}%", 100.0 * o.trainable as f64 / total as f64)
            },
        ];
        for (_, a) in &o.accuracy {
            row.push(format!("{:.1}", 100.0 * a));
        }
        row.push(format!("{:.1}", 100.0 * o.avg));
        table.row(row);
    }
    Ok((table, outcomes))
}

/// Fig 5 — ΔW spectra table from table2 outcomes.
pub fn fig5_from(outcomes: &[PeftOutcome]) -> Table {
    let mut table = Table::new(
        "Fig 5 — singular values of ΔW (full-rank for CLOVER/full-FT, capped for LoRA)",
        &["method", "numerical_rank", "top8_sv"],
    );
    for o in outcomes {
        if o.delta_s.is_empty() {
            continue;
        }
        let topk: Vec<String> = o.delta_s.iter().take(8).map(|x| format!("{x:.3}")).collect();
        table.row(vec![
            o.method.clone(),
            clover::analysis::numerical_rank(&o.delta_s, 1e-3).to_string(),
            topk.join(" "),
        ]);
    }
    table
}

/// Fig 6 — intruder-dimension counts from table2 outcomes.
pub fn fig6_from(outcomes: &[PeftOutcome]) -> Table {
    let mut table = Table::new(
        "Fig 6 — intruder dimensions among top-8 singular vectors (cos < 0.7)",
        &["method", "intruders"],
    );
    for o in outcomes {
        if o.delta_s.is_empty() {
            continue;
        }
        table.row(vec![o.method.clone(), o.intruders.to_string()]);
    }
    table
}

// ---------------------------------------------------------------------
// Tables 3 & 4: accounting + dataset details
// ---------------------------------------------------------------------

pub fn table3(rt: &Runtime, opts: &ExpOpts) -> Result<Table> {
    let entry = rt.manifest().config(&opts.preset)?.clone();
    let total = crate::model::manifest::ConfigEntry::param_count(&entry.params_dense);
    let mut table = Table::new(
        &format!("Table 3 — trainable parameters ({}; total {total})", opts.preset),
        &["method", "target", "trainable", "pct"],
    );
    let lora = peft::account("LoRA", total, &entry.params_lora, &["a_", "b_"]);
    let dora = peft::account("DoRA", total, &entry.params_dora, &["a_", "b_", "m_"]);
    let cl = peft::account("CLOVER", total, &entry.params_facud, &["s_"]);
    for (acc, tgt) in [(&lora, "Q,K,V,U,D"), (&dora, "Q,K,V,U,D"), (&cl, "Q-K,V-O,U-D")] {
        table.row(vec![
            acc.method.clone(), tgt.into(),
            acc.trainable.to_string(), format!("{:.2}%", acc.pct()),
        ]);
    }
    // The paper's LLaMA-2-7B identity (Appendix A.2).
    let (l32, cs) = peft::llama2_7b_table3();
    table.row(vec![
        "LoRA r=32 (LLaMA-2-7B)".into(), "per-layer".into(), l32.to_string(), "-".into(),
    ]);
    table.row(vec![
        "CLOVER (LLaMA-2-7B)".into(), "per-layer".into(), cs.to_string(), "-".into(),
    ]);
    Ok(table)
}

pub fn table4(opts: &ExpOpts) -> Table {
    let tasks = all_tasks(opts.seed, if opts.quick { 1 } else { 2 });
    let mut table = Table::new("Table 4 — synthetic task suite", &["task", "train", "test", "about"]);
    for t in &tasks {
        table.row(vec![
            t.name.into(), t.train.len().to_string(), t.test.len().to_string(), t.about.into(),
        ]);
    }
    table
}
