//! High-level operations shared by the CLI, examples, and benches:
//! initialization, pretraining, the prune→recover pipeline, and decode.

use anyhow::{Context, Result};
use std::collections::BTreeMap;

use crate::clover;
use crate::data::batch::TokenStream;
use crate::model::manifest::ConfigEntry;
use crate::model::params::ParamSet;
use crate::runtime::Runtime;
use crate::tensor::{Tensor, TensorI, Value};
use crate::util::rng::Rng;

use super::trainer::{train_loop, LoopOpts, TrainState};

/// Run the `init` program: fresh dense parameters for a config.
pub fn init_params(rt: &Runtime, config: &str, seed: i32) -> Result<ParamSet> {
    let entry = rt.manifest().config(config)?.clone();
    let outs = rt.run(config, "init", &[Value::I32(TensorI::scalar(seed))])?;
    let tensors: Vec<Tensor> = outs.into_iter()
        .map(|v| v.into_f32())
        .collect::<Result<_>>()?;
    let spec = if entry.kind == "seq2seq" { &entry.params_dense } else { &entry.params_dense };
    ParamSet::from_flat(spec, tensors)
}

/// LM batch provider closure over a token stream.
pub fn lm_batcher<'a>(
    stream: &'a TokenStream,
    b: usize,
    t: usize,
    seed: u64,
) -> impl FnMut(usize) -> BTreeMap<String, Value> + 'a {
    let mut rng = Rng::new(seed);
    move |_i| {
        let (inp, tgt) = stream.train_batch(&mut rng, b, t);
        let mut m = BTreeMap::new();
        m.insert("inputs".to_string(), Value::I32(inp));
        m.insert("targets".to_string(), Value::I32(tgt));
        m
    }
}

/// Knobs for [`pretrain`]: step budget, learning rate, data seed, and the
/// log tag.
#[derive(Clone, Debug)]
pub struct PretrainOpts {
    pub steps: usize,
    pub lr: f64,
    pub seed: u64,
    pub tag: String,
}

/// Pretrain dense params on a token stream; returns the loss curve.
pub fn pretrain(
    rt: &Runtime,
    config: &str,
    params: ParamSet,
    stream: &TokenStream,
    opts: &PretrainOpts,
) -> Result<(ParamSet, Vec<(usize, f32)>)> {
    let entry = rt.manifest().config(config)?;
    let (b, t) = (entry.dim("train_batch")?, entry.dim("seq_len")?);
    let mut state = TrainState::new(vec![params]);
    let loop_opts = LoopOpts {
        steps: opts.steps,
        lr: opts.lr,
        schedule: "cosine".into(),
        warmup: (opts.steps / 20).max(2),
        log_every: (opts.steps / 10).max(1),
        tag: opts.tag.clone(),
    };
    let curve = train_loop(rt, config, "train_full", &mut state, &loop_opts,
                           lm_batcher(stream, b, t, opts.seed))?;
    Ok((state.sets.remove(0), curve))
}

/// Factorize dense params at the rank implied by `ratio`, using either the
/// CLOVER transform or the vanilla norm-product baseline.  Returns
/// (factorized params, rank).
pub fn prune_to_ratio(
    entry: &ConfigEntry,
    dense: &ParamSet,
    ratio: f64,
    method: &str,
) -> Result<(ParamSet, usize)> {
    let dh = entry.dim("d_head")?;
    let h = entry.dim("n_heads")?;
    let r = clover::rank_for_ratio(dh, ratio, &entry.ranks);
    let fac_spec = entry.params_fac.get(&r)
        .with_context(|| format!("no factorized artifacts at rank {r}"))?;
    let fac = match method {
        "vanilla" => clover::vanilla_prune(dense, fac_spec, h, &clover::DECODER_NAMING)?,
        _ => clover::clover_transform(dense, fac_spec, h, &clover::DECODER_NAMING)?.0,
    };
    Ok((fac, r))
}

/// Knobs for [`recover`]: the factorization rank, the fine-tune mode
/// (`"attn"` trains all factorized attention tensors — Table 1
/// "CLOVER"/"Vanilla" columns; `"s"` trains only the singular-value
/// matrices — CLOVER†), the step budget, learning rate, and data seed.
#[derive(Clone, Debug)]
pub struct RecoverOpts {
    pub r: usize,
    pub mode: String,
    pub steps: usize,
    pub lr: f64,
    pub seed: u64,
}

/// Recovery fine-tune of a pruned model (see [`RecoverOpts`]).
pub fn recover(
    rt: &Runtime,
    config: &str,
    fac: ParamSet,
    stream: &TokenStream,
    opts: &RecoverOpts,
) -> Result<(ParamSet, Vec<(usize, f32)>)> {
    let entry = rt.manifest().config(config)?;
    let (b, t) = (entry.dim("train_batch")?, entry.dim("seq_len")?);
    let r = opts.r;
    let program = match opts.mode.as_str() {
        "s" => format!("train_clover_s_r{r}"),
        _ => format!("train_fac_attn_r{r}"),
    };
    let mut state = TrainState::new(vec![fac]);
    let loop_opts = LoopOpts {
        steps: opts.steps,
        lr: opts.lr,
        schedule: "linear".into(),
        warmup: (opts.steps / 20).max(1),
        log_every: (opts.steps / 5).max(1),
        tag: format!("recover-{}-r{r}", opts.mode),
    };
    let curve = train_loop(rt, config, &program, &mut state, &loop_opts,
                           lm_batcher(stream, b, t, opts.seed))?;
    Ok((state.sets.remove(0), curve))
}

/// Perplexity of a factorized model at rank r.
pub fn fac_perplexity(
    rt: &Runtime,
    config: &str,
    fac: &ParamSet,
    r: usize,
    stream: &TokenStream,
    max_batches: usize,
) -> Result<f64> {
    super::eval::perplexity(rt, config, &format!("nll_fac_r{r}"), fac, stream, max_batches)
}

/// Greedy decode with the batched KV-cache artifacts; returns generated
/// token rows (prompt included).  Used by the serve engine and examples.
pub fn greedy_decode(
    rt: &Runtime,
    config: &str,
    program: &str,
    params: &ParamSet,
    prompts: &[Vec<i32>],
    max_new: usize,
) -> Result<Vec<Vec<i32>>> {
    let sig = rt.manifest().config(config)?.program(program)?.clone();
    // cache shapes come from the program signature
    let cache_spec = sig.inputs.iter().find(|a| a.name.ends_with("_cache"))
        .context("decode program has no cache input")?;
    let cache_shape = cache_spec.shape.clone();
    let b = cache_shape[1];
    let c = cache_shape[3];
    anyhow::ensure!(prompts.len() <= b, "too many prompts for decode batch {b}");
    let v = rt.manifest().config(config)?.dim("vocab")?;

    let mut kc = Tensor::zeros(&cache_shape);
    let mut vc = Tensor::zeros(&cache_shape);
    let mut rows: Vec<Vec<i32>> = (0..b)
        .map(|i| prompts.get(i).cloned().unwrap_or_else(|| vec![0]))
        .collect();
    let max_prompt = rows.iter().map(|r| r.len()).max().unwrap_or(1);
    let total = (max_prompt + max_new).min(c);

    for pos in 0..total {
        let toks: Vec<i32> = rows.iter()
            .map(|r| *r.get(pos).unwrap_or(r.last().unwrap_or(&0)))
            .collect();
        let mut args: Vec<Value> =
            params.flat().iter().map(|&t| Value::F32(t.clone())).collect();
        args.push(Value::F32(kc));
        args.push(Value::F32(vc));
        args.push(Value::I32(TensorI::new(vec![b], toks)));
        // Decode artifacts take per-lane position vectors; this lockstep
        // path runs every lane at the same depth.
        args.push(Value::I32(TensorI::new(vec![b], vec![pos as i32; b])));
        let mut outs = rt.run(config, program, &args)?;
        let vc_new = outs.pop().unwrap().into_f32()?;
        let kc_new = outs.pop().unwrap().into_f32()?;
        let logits = outs.pop().unwrap().into_f32()?; // [B, V]
        kc = kc_new;
        vc = vc_new;
        for (i, row) in rows.iter_mut().enumerate() {
            if pos + 1 >= row.len() && row.len() < total {
                // past the prompt: append argmax
                let base = i * v;
                row.push(crate::util::argmax(&logits.data()[base..base + v]) as i32);
            }
        }
    }
    rows.truncate(prompts.len());
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn art() -> String {
        format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
    }

    #[test]
    fn prune_both_methods_tiny() {
        let Some(rt) = crate::testing::runtime_or_skip(&art()) else { return };
        let entry = rt.manifest().config("tiny").unwrap().clone();
        let dense = init_params(&rt, "tiny", 3).unwrap();
        for method in ["clover", "vanilla"] {
            let (fac, r) = prune_to_ratio(&entry, &dense, 0.5, method).unwrap();
            assert_eq!(r, 8);
            assert_eq!(fac.get("u_qk").unwrap().shape(), &[2, 4, 64, 8]);
        }
    }

    #[test]
    fn clover_full_rank_matches_dense_nll() {
        // The end-to-end seal: rust CLOVER transform at r=d, run through the
        // factorized HLO, reproduces the dense model's loss.
        let Some(rt) = crate::testing::runtime_or_skip(&art()) else { return };
        let entry = rt.manifest().config("tiny").unwrap().clone();
        let dense = init_params(&rt, "tiny", 11).unwrap();
        let (fac, r) = prune_to_ratio(&entry, &dense, 0.0, "clover").unwrap();
        assert_eq!(r, entry.dim("d_head").unwrap());
        let (b, t) = (entry.dim("train_batch").unwrap(), entry.dim("seq_len").unwrap());
        let mut rng = Rng::new(1);
        let toks: Vec<i32> = (0..b * t).map(|_| rng.below(256) as i32).collect();
        let inp = TensorI::new(vec![b, t], toks.clone());
        let tgt = TensorI::new(vec![b, t], toks);
        let mut args: Vec<Value> = dense.flat().iter().map(|&x| Value::F32(x.clone())).collect();
        args.push(Value::I32(inp.clone()));
        args.push(Value::I32(tgt.clone()));
        let dense_loss = rt.run_scalar("tiny", "nll", &args, 0).unwrap();
        let mut fargs: Vec<Value> = fac.flat().iter().map(|&x| Value::F32(x.clone())).collect();
        fargs.push(Value::I32(inp));
        fargs.push(Value::I32(tgt));
        let fac_loss = rt.run_scalar("tiny", &format!("nll_fac_r{r}"), &fargs, 0).unwrap();
        assert!((dense_loss - fac_loss).abs() < 1e-2,
                "dense {dense_loss} vs clover-full-rank {fac_loss}");
    }

    #[test]
    fn greedy_decode_shapes() {
        let Some(rt) = crate::testing::runtime_or_skip(&art()) else { return };
        let dense = init_params(&rt, "tiny", 5).unwrap();
        let rows = greedy_decode(&rt, "tiny", "decode_b1", &dense, &[vec![1, 2, 3]], 4).unwrap();
        assert_eq!(rows.len(), 1);
        assert_eq!(rows[0].len(), 7);
        assert_eq!(&rows[0][..3], &[1, 2, 3]);
    }
}
