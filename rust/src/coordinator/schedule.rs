//! Learning-rate schedules (linear / cosine decay with warmup).

/// LR at `step` (0-based) of `total` steps with `warmup` linear-ramp steps.
pub fn lr_at(kind: &str, base: f64, step: usize, total: usize, warmup: usize) -> f64 {
    if warmup > 0 && step < warmup {
        return base * (step + 1) as f64 / warmup as f64;
    }
    let span = total.saturating_sub(warmup).max(1) as f64;
    let t = (step.saturating_sub(warmup)) as f64 / span;
    let t = t.min(1.0);
    match kind {
        "cosine" => base * 0.5 * (1.0 + (std::f64::consts::PI * t).cos()),
        "constant" => base,
        _ => base * (1.0 - t), // linear
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps() {
        assert!(lr_at("linear", 1.0, 0, 100, 10) < lr_at("linear", 1.0, 9, 100, 10));
        assert!((lr_at("linear", 1.0, 9, 100, 10) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn linear_decays_to_zero() {
        let end = lr_at("linear", 1.0, 99, 100, 0);
        assert!(end < 0.02);
    }

    #[test]
    fn cosine_midpoint_half() {
        let mid = lr_at("cosine", 1.0, 50, 100, 0);
        assert!((mid - 0.5).abs() < 0.02);
    }

    #[test]
    fn constant_is_constant() {
        assert_eq!(lr_at("constant", 0.3, 5, 100, 0), 0.3);
        assert_eq!(lr_at("constant", 0.3, 95, 100, 0), 0.3);
    }
}
