//! Coordinator: training/eval loops and the experiment runners that
//! regenerate every table and figure (see DESIGN.md §4 for the index).

pub mod eval;
pub mod experiments;
pub mod ops;
pub mod schedule;
pub mod trainer;

pub use ops::{
    fac_perplexity, greedy_decode, init_params, pretrain, prune_to_ratio, recover, PretrainOpts,
    RecoverOpts,
};
pub use trainer::{train_loop, train_step, LoopOpts, TrainState};
