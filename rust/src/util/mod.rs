//! Small shared utilities: deterministic RNG, timing, logging.
//!
//! The crate deliberately avoids external dependencies beyond `xla` +
//! `anyhow` (this environment vendors only the xla crate's closure), so the
//! usual suspects (rand, log, indicatif) are replaced by these few dozen
//! lines.

pub mod rng;
pub mod sync;

use std::time::Instant;

/// Wall-clock stopwatch for coarse phase timing in the coordinator and the
/// bench harnesses.
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn new() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::new()
    }
}

/// Log level for [`log`]; controlled by the `CLOVER_LOG` env var
/// (`debug`/`info`/`warn`, default `info`).
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
}

pub fn log_enabled(level: Level) -> bool {
    let min = match std::env::var("CLOVER_LOG").as_deref() {
        Ok("debug") => Level::Debug,
        Ok("warn") => Level::Warn,
        _ => Level::Info,
    };
    level >= min
}

/// Timestamped stderr logger (stdout is reserved for report tables).
pub fn log(level: Level, msg: &str) {
    if !log_enabled(level) {
        return;
    }
    let tag = match level {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
    };
    let t = std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0);
    eprintln!("[{tag} {t:.3}] {msg}");
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::log($crate::util::Level::Info, &format!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::log($crate::util::Level::Debug, &format!($($arg)*)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::util::log($crate::util::Level::Warn, &format!($($arg)*)) };
}

/// Format a byte count for human-readable KV-cache reports.
pub fn human_bytes(n: usize) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut x = n as f64;
    let mut u = 0;
    while x >= 1024.0 && u < UNITS.len() - 1 {
        x /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{x:.2} {}", UNITS[u])
    }
}

/// Index of the maximum element; ties resolve to the lowest index.  Shared
/// by greedy decoding in the coordinator and the serving sampler.
pub fn argmax(xs: &[f32]) -> usize {
    let mut best = 0usize;
    let mut bestv = f32::NEG_INFINITY;
    for (i, &x) in xs.iter().enumerate() {
        if x > bestv {
            bestv = x;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_ties_to_lowest_index() {
        assert_eq!(argmax(&[0.5, 2.0, 2.0, -1.0]), 1);
        assert_eq!(argmax(&[f32::NEG_INFINITY]), 0);
    }

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.00 MiB");
    }

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::new();
        assert!(sw.elapsed_s() >= 0.0);
    }
}
