//! Deterministic PRNG (xoshiro256**) used by the data pipeline, adapter
//! init, and the in-repo property-test harness.
//!
//! Every experiment seeds explicitly (seeds recorded in EXPERIMENTS.md), so
//! runs are reproducible bit-for-bit; there is deliberately no global RNG.

/// xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that small consecutive seeds give
    /// decorrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        // Lemire-style rejection-free for our (non-crypto) purposes.
        (self.uniform() * n as f64) as usize % n
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 > 1e-300 {
                let u2 = self.uniform();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Vector of N(0, std²) f32 samples.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32 * std).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.uniform() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Pick a uniform element.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_decorrelated() {
        let x = Rng::new(0).next_u64();
        let y = Rng::new(1).next_u64();
        assert_ne!(x, y);
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(2);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let xs: Vec<f64> = (0..20000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 2];
        for _ in 0..2000 {
            counts[r.weighted(&[1.0, 9.0])] += 1;
        }
        assert!(counts[1] > counts[0] * 5);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
