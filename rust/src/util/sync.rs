//! Sync-primitive shim: `std::sync` by default, loom types under
//! `--features loom`.
//!
//! The gateway worker, engine observability, and the KV-lane lifecycle
//! share a small set of primitives (`Arc`, `Mutex`, atomics, `thread`).
//! Importing them from here instead of `std::sync` lets the loom lane
//! (`cargo test --features loom --test loom` in CI) re-run the modeled
//! protocols — ingress admission vs cancel, same-iteration lane reclaim,
//! speculative rollback vs slot free — under schedule exploration with
//! the *same* types the production build links.
//!
//! `mpsc` is deliberately absent: loom does not model std channels, so
//! channel-shaped protocols are modeled in `tests/loom.rs` against the
//! primitives they decompose into.

#[cfg(feature = "loom")]
pub use loom::sync::{atomic, Arc, Condvar, Mutex, MutexGuard};
#[cfg(feature = "loom")]
pub use loom::thread;

#[cfg(not(feature = "loom"))]
pub use std::sync::{atomic, Arc, Condvar, Mutex, MutexGuard};
#[cfg(not(feature = "loom"))]
pub use std::thread;
