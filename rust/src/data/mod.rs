//! Data pipeline: synthetic corpora, BPE tokenizer, task suites, signal
//! rendering, and batching.
//!
//! Everything is seeded and deterministic; there are no external datasets
//! (the reproduction substitutes WikiText-2 / OpenWebText / Commonsense-170k
//! / LibriSpeech per DESIGN.md §2).

pub mod batch;
pub mod corpus;
pub mod signal;
pub mod tasks;
pub mod tokenizer;

pub use batch::TokenStream;
pub use corpus::Corpus;
pub use signal::SignalRenderer;
pub use tasks::{all_tasks, Example, TaskData};
pub use tokenizer::Tokenizer;

use crate::util::rng::Rng;

/// Build the standard pretraining pipeline for a decoder config: generate
/// a corpus, train a BPE tokenizer to the model's vocab, tokenize, split.
pub fn build_lm_stream(
    corpus_name: &str,
    vocab: usize,
    n_chars: usize,
    seed: u64,
) -> (Tokenizer, TokenStream) {
    let corpus = Corpus::by_name(corpus_name, seed);
    let mut rng = Rng::new(seed);
    let text = corpus.generate(&mut rng, n_chars);
    let tok = Tokenizer::train(&text, vocab);
    let ids = tok.encode(&text);
    (tok, TokenStream::new(ids, 0.1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lm_stream_end_to_end() {
        let (tok, stream) = build_lm_stream("mixture", 256, 30_000, 9);
        assert_eq!(tok.vocab_size(), 256);
        assert!(stream.train_len() > 5_000);
        assert!(stream.valid_len() > 500);
        let mut rng = Rng::new(0);
        let (i, t) = stream.train_batch(&mut rng, 2, 32);
        assert_eq!(i.shape(), &[2, 32]);
        assert!(i.data().iter().all(|&x| (x as usize) < 256));
        assert!(t.data().iter().all(|&x| (x as usize) < 256));
    }
}
