//! Synthetic text corpora — the stand-in for WikiText-2 / OpenWebText.
//!
//! Three generators with genuinely different statistics (so perplexity
//! differences between pruning methods are driven by model structure, not
//! corpus triviality):
//!
//! * **Zipf unigram** — heavy-tailed word frequencies over a synthetic
//!   vocabulary of letter words.
//! * **Markov bigram-mix** — a K-state latent-topic chain; each state owns
//!   a sparse bigram table, so there is real sequential structure for
//!   attention heads to learn.
//! * **Templated sentences** — subject/verb/object grammar with agreement
//!   constraints (long-range dependency: the closing tag must match the
//!   opener several tokens back).
//!
//! `mixture` interleaves all three at the document level.

use crate::util::rng::Rng;

/// Build a deterministic synthetic word list ("va", "ko", "zuri", ...).
fn word_list(n: usize, rng: &mut Rng) -> Vec<String> {
    const C: [&str; 12] = ["k", "t", "s", "m", "n", "r", "v", "z", "p", "g", "d", "b"];
    const V: [&str; 5] = ["a", "e", "i", "o", "u"];
    let mut words = Vec::with_capacity(n);
    let mut seen = std::collections::HashSet::new();
    while words.len() < n {
        let syllables = 1 + rng.below(3);
        let mut w = String::new();
        for _ in 0..syllables {
            w.push_str(rng.choice::<&str>(&C[..]));
            w.push_str(rng.choice::<&str>(&V[..]));
        }
        if seen.insert(w.clone()) {
            words.push(w);
        }
    }
    words
}

/// Zipf-distributed unigram text.
pub struct ZipfCorpus {
    words: Vec<String>,
    weights: Vec<f64>,
}

impl ZipfCorpus {
    pub fn new(vocab_words: usize, exponent: f64, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x5a5a);
        let words = word_list(vocab_words, &mut rng);
        let weights = (1..=vocab_words).map(|r| 1.0 / (r as f64).powf(exponent)).collect();
        Self { words, weights }
    }

    pub fn sentence(&self, rng: &mut Rng, len: usize) -> String {
        let mut parts = Vec::with_capacity(len);
        for _ in 0..len {
            parts.push(self.words[rng.weighted(&self.weights)].as_str());
        }
        parts.join(" ")
    }
}

/// Latent-topic Markov bigram corpus.
pub struct MarkovCorpus {
    words: Vec<String>,
    /// transition[topic][word] -> list of (next_word, weight)
    tables: Vec<Vec<Vec<(usize, f64)>>>,
    n_topics: usize,
}

impl MarkovCorpus {
    pub fn new(vocab_words: usize, n_topics: usize, branching: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xa1a1);
        let words = word_list(vocab_words, &mut rng);
        let mut tables = Vec::with_capacity(n_topics);
        for _ in 0..n_topics {
            let mut table = Vec::with_capacity(vocab_words);
            for _ in 0..vocab_words {
                let succ: Vec<(usize, f64)> = (0..branching)
                    .map(|_| (rng.below(vocab_words), rng.uniform() + 0.1))
                    .collect();
                table.push(succ);
            }
            tables.push(table);
        }
        Self { words, tables, n_topics }
    }

    pub fn sentence(&self, rng: &mut Rng, len: usize) -> String {
        let topic = rng.below(self.n_topics);
        let mut cur = rng.below(self.words.len());
        let mut parts = vec![self.words[cur].as_str()];
        for _ in 1..len {
            let succ = &self.tables[topic][cur];
            let weights: Vec<f64> = succ.iter().map(|(_, w)| *w).collect();
            cur = succ[rng.weighted(&weights)].0;
            parts.push(self.words[cur].as_str());
        }
        parts.join(" ")
    }
}

/// Templated grammar with an agreement dependency.
pub struct TemplateCorpus {
    subjects: Vec<String>,
    verbs: Vec<String>,
    objects: Vec<String>,
}

impl TemplateCorpus {
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xc3c3);
        Self {
            subjects: word_list(24, &mut rng),
            verbs: word_list(16, &mut rng),
            objects: word_list(24, &mut rng),
        }
    }

    pub fn sentence(&self, rng: &mut Rng, _len: usize) -> String {
        // "<s> SUBJ who VERB OBJ and OBJ , VERB SUBJ </s>" — the trailing
        // SUBJ repeats the opener: a long-range copy the model can learn.
        let s = rng.choice(&self.subjects).clone();
        let v1 = rng.choice(&self.verbs);
        let o1 = rng.choice(&self.objects);
        let o2 = rng.choice(&self.objects);
        let v2 = rng.choice(&self.verbs);
        format!("{s} who {v1} {o1} and {o2} , {v2} {s} .")
    }
}

/// Document-level mixture of the three generators.
pub enum Corpus {
    Zipf(ZipfCorpus),
    Markov(MarkovCorpus),
    Mixture(ZipfCorpus, MarkovCorpus, TemplateCorpus),
}

impl Corpus {
    pub fn by_name(name: &str, seed: u64) -> Self {
        match name {
            "zipf" => Corpus::Zipf(ZipfCorpus::new(400, 1.1, seed)),
            "markov" => Corpus::Markov(MarkovCorpus::new(300, 4, 6, seed)),
            _ => Corpus::Mixture(
                ZipfCorpus::new(400, 1.1, seed),
                MarkovCorpus::new(300, 4, 6, seed),
                TemplateCorpus::new(seed),
            ),
        }
    }

    /// Generate ~`n_chars` of newline-separated sentences.
    pub fn generate(&self, rng: &mut Rng, n_chars: usize) -> String {
        let mut out = String::with_capacity(n_chars + 128);
        while out.len() < n_chars {
            let len = 8 + rng.below(16);
            let s = match self {
                Corpus::Zipf(z) => z.sentence(rng, len),
                Corpus::Markov(m) => m.sentence(rng, len),
                Corpus::Mixture(z, m, t) => match rng.below(3) {
                    0 => z.sentence(rng, len),
                    1 => m.sentence(rng, len),
                    _ => t.sentence(rng, len),
                },
            };
            out.push_str(&s);
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_generation() {
        let c = Corpus::by_name("mixture", 7);
        let a = c.generate(&mut Rng::new(1), 1000);
        let b = c.generate(&mut Rng::new(1), 1000);
        assert_eq!(a, b);
        assert!(a.len() >= 1000);
    }

    #[test]
    fn corpora_differ() {
        let z = Corpus::by_name("zipf", 7).generate(&mut Rng::new(1), 500);
        let m = Corpus::by_name("markov", 7).generate(&mut Rng::new(1), 500);
        assert_ne!(z, m);
    }

    #[test]
    fn zipf_head_is_heavy() {
        let z = ZipfCorpus::new(100, 1.2, 0);
        let mut rng = Rng::new(3);
        let text = z.sentence(&mut rng, 5000);
        let mut counts = std::collections::HashMap::new();
        for w in text.split_whitespace() {
            *counts.entry(w).or_insert(0usize) += 1;
        }
        let mut freq: Vec<usize> = counts.values().copied().collect();
        freq.sort_unstable_by(|a, b| b.cmp(a));
        // the most frequent word should dominate the 20th by a wide margin
        assert!(freq[0] > freq.get(19).copied().unwrap_or(1) * 3);
    }

    #[test]
    fn template_agreement() {
        let t = TemplateCorpus::new(0);
        let mut rng = Rng::new(5);
        for _ in 0..20 {
            let s = t.sentence(&mut rng, 0);
            let toks: Vec<&str> = s.split_whitespace().collect();
            // first token repeats as second-to-last (before the period)
            assert_eq!(toks[0], toks[toks.len() - 2], "{s}");
        }
    }

    #[test]
    fn word_list_unique() {
        let mut rng = Rng::new(1);
        let words = word_list(200, &mut rng);
        let set: std::collections::HashSet<_> = words.iter().collect();
        assert_eq!(set.len(), 200);
    }
}
