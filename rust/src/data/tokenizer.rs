//! Byte-level BPE tokenizer: trainer + encoder + decoder.
//!
//! Trained on the synthetic corpus up to the model's vocab size.  Token ids
//! 0..255 are raw bytes; merges occupy 256..vocab.  Greedy longest-match
//! encoding with a trie; exact byte-level round-trip by construction.

use anyhow::Result;
use std::collections::HashMap;

/// A trained BPE vocabulary.
#[derive(Clone, Debug)]
pub struct Tokenizer {
    /// token id -> byte sequence
    pieces: Vec<Vec<u8>>,
    /// trie over piece bytes for greedy longest-match
    trie: Trie,
}

#[derive(Clone, Debug, Default)]
struct Trie {
    /// node -> (byte -> node); node 0 is the root
    next: Vec<HashMap<u8, usize>>,
    /// node -> token id ending here
    accept: Vec<Option<u32>>,
}

impl Trie {
    fn new() -> Self {
        Self { next: vec![HashMap::new()], accept: vec![None] }
    }

    fn insert(&mut self, bytes: &[u8], id: u32) {
        let mut node = 0usize;
        for &b in bytes {
            let n = self.next.len();
            node = *self.next[node].entry(b).or_insert_with(|| n);
            if node == n {
                self.next.push(HashMap::new());
                self.accept.push(None);
            }
        }
        self.accept[node] = Some(id);
    }

    /// Longest match at `text[pos..]`: (token id, length).
    fn longest(&self, text: &[u8], pos: usize) -> (u32, usize) {
        let mut node = 0usize;
        let mut best = (text[pos] as u32, 1); // byte fallback always matches
        for (i, &b) in text[pos..].iter().enumerate() {
            match self.next[node].get(&b) {
                Some(&n) => {
                    node = n;
                    if let Some(id) = self.accept[node] {
                        best = (id, i + 1);
                    }
                }
                None => break,
            }
        }
        best
    }
}

impl Tokenizer {
    /// Byte-only tokenizer (vocab 256) — the fallback when no training text
    /// is supplied.
    pub fn bytes_only() -> Self {
        Self::from_pieces((0..256u32).map(|b| vec![b as u8]).collect())
    }

    fn from_pieces(pieces: Vec<Vec<u8>>) -> Self {
        let mut trie = Trie::new();
        for (id, p) in pieces.iter().enumerate() {
            trie.insert(p, id as u32);
        }
        Self { pieces, trie }
    }

    /// Train BPE merges on `text` until `vocab_size` pieces exist.
    ///
    /// Classic greedy pair-merge on a word-frequency table (words =
    /// whitespace-split chunks with the separator attached, so spaces are
    /// learned like any other byte).
    pub fn train(text: &str, vocab_size: usize) -> Self {
        assert!(vocab_size >= 256, "vocab must cover raw bytes");
        // word -> count, each word a Vec<token id> starting as raw bytes
        let mut word_counts: HashMap<Vec<u32>, usize> = HashMap::new();
        for chunk in text.split_inclusive([' ', '\n']) {
            let ids: Vec<u32> = chunk.bytes().map(|b| b as u32).collect();
            if !ids.is_empty() {
                *word_counts.entry(ids).or_insert(0) += 1;
            }
        }
        let mut words: Vec<(Vec<u32>, usize)> = word_counts.into_iter().collect();
        words.sort(); // deterministic order

        let mut pieces: Vec<Vec<u8>> = (0..256u32).map(|b| vec![b as u8]).collect();
        while pieces.len() < vocab_size {
            // Count adjacent pairs.
            let mut pair_counts: HashMap<(u32, u32), usize> = HashMap::new();
            for (w, c) in &words {
                for pair in w.windows(2) {
                    *pair_counts.entry((pair[0], pair[1])).or_insert(0) += c;
                }
            }
            // Deterministic argmax: max count, then smallest pair ids.
            let best = pair_counts.into_iter()
                .max_by(|a, b| a.1.cmp(&b.1).then_with(|| b.0.cmp(&a.0)));
            let ((a, b), count) = match best {
                Some(x) if x.1 >= 2 => x,
                _ => break, // nothing worth merging
            };
            let _ = count;
            let new_id = pieces.len() as u32;
            let mut merged_piece = pieces[a as usize].clone();
            merged_piece.extend_from_slice(&pieces[b as usize]);
            pieces.push(merged_piece);
            // Apply the merge to every word.
            for (w, _) in words.iter_mut() {
                let mut out = Vec::with_capacity(w.len());
                let mut i = 0;
                while i < w.len() {
                    if i + 1 < w.len() && w[i] == a && w[i + 1] == b {
                        out.push(new_id);
                        i += 2;
                    } else {
                        out.push(w[i]);
                        i += 1;
                    }
                }
                *w = out;
            }
        }
        Self::from_pieces(pieces)
    }

    pub fn vocab_size(&self) -> usize {
        self.pieces.len()
    }

    /// Greedy longest-match encoding.
    pub fn encode(&self, text: &str) -> Vec<i32> {
        let bytes = text.as_bytes();
        let mut out = Vec::with_capacity(bytes.len() / 2 + 1);
        let mut pos = 0;
        while pos < bytes.len() {
            let (id, len) = self.trie.longest(bytes, pos);
            out.push(id as i32);
            pos += len;
        }
        out
    }

    pub fn decode(&self, ids: &[i32]) -> String {
        let mut bytes = Vec::new();
        for &id in ids {
            if let Some(p) = self.pieces.get(id as usize) {
                bytes.extend_from_slice(p);
            }
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// Serialize to a small text format (piece hex per line).
    pub fn save(&self, path: &std::path::Path) -> Result<()> {
        let mut out = String::new();
        for p in &self.pieces {
            for b in p {
                out.push_str(&format!("{b:02x}"));
            }
            out.push('\n');
        }
        std::fs::write(path, out)?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let mut pieces = Vec::new();
        for line in text.lines() {
            let mut bytes = Vec::with_capacity(line.len() / 2);
            let mut chars = line.as_bytes().chunks(2);
            for ch in &mut chars {
                bytes.push(u8::from_str_radix(std::str::from_utf8(ch)?, 16)?);
            }
            pieces.push(bytes);
        }
        Ok(Self::from_pieces(pieces))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop;
    use crate::util::rng::Rng;

    #[test]
    fn bytes_only_roundtrip() {
        let t = Tokenizer::bytes_only();
        let s = "hello, wörld!\n";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn trained_roundtrip_property() {
        let corpus = crate::data::corpus::Corpus::by_name("mixture", 3);
        let text = corpus.generate(&mut Rng::new(0), 20_000);
        let tok = Tokenizer::train(&text, 512);
        assert_eq!(tok.vocab_size(), 512);
        prop("BPE roundtrip", 20, |rng| {
            let corpus = crate::data::corpus::Corpus::by_name("mixture", 3);
            let sample = corpus.generate(rng, 200);
            let ids = tok.encode(&sample);
            if tok.decode(&ids) != sample {
                return Err("roundtrip mismatch".into());
            }
            Ok(())
        });
    }

    #[test]
    fn compression_beats_bytes() {
        let corpus = crate::data::corpus::Corpus::by_name("zipf", 5);
        let text = corpus.generate(&mut Rng::new(1), 30_000);
        let tok = Tokenizer::train(&text, 512);
        let sample = corpus.generate(&mut Rng::new(2), 2_000);
        let n_ids = tok.encode(&sample).len();
        // trained BPE should compress ~2x over raw bytes on in-domain text
        assert!(n_ids * 3 < sample.len() * 2, "ids {n_ids} bytes {}", sample.len());
    }

    #[test]
    fn ids_in_range() {
        let text = "abc abc abc abd abd xyz";
        let tok = Tokenizer::train(text, 260);
        for id in tok.encode(text) {
            assert!((id as usize) < tok.vocab_size());
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let tok = Tokenizer::train("the quick brown fox the quick", 300);
        let path = std::env::temp_dir().join(format!("clover_tok_{}", std::process::id()));
        tok.save(&path).unwrap();
        let back = Tokenizer::load(&path).unwrap();
        assert_eq!(back.vocab_size(), tok.vocab_size());
        assert_eq!(back.encode("the quick"), tok.encode("the quick"));
        std::fs::remove_file(path).ok();
    }
}
