//! Eight synthetic "commonsense-style" subtasks — the Table-2 stand-in for
//! BoolQ / PIQA / SIQA / HellaSwag / WinoGrande / ARC-e / ARC-c / OBQA.
//!
//! Each task samples from a seeded latent *world* (taxonomy, tool-affordance
//! table, social-response rules, ordering relation...), renders examples as
//! `"<prompt> answer: <option>"` text, and ships predefined train/test
//! splits (sizes proportional to Table 4).  What matters for the
//! reproduction is the *format* — multiple-choice scored by option
//! log-likelihood under the LM — and that the tasks are learnable by
//! fine-tuning but non-trivial at init, mirroring how the paper's PEFT
//! ranking is measured.

use crate::util::rng::Rng;

/// One multiple-choice example.
#[derive(Clone, Debug)]
pub struct Example {
    pub prompt: String,
    pub options: Vec<String>,
    pub gold: usize,
}

impl Example {
    /// Training text (prompt + gold answer), Commonsense-170k style.
    pub fn train_text(&self) -> String {
        format!("{} answer: {}\n", self.prompt, self.options[self.gold])
    }

    /// Candidate text for option `i` (scored at eval time).
    pub fn option_text(&self, i: usize) -> String {
        format!("{} answer: {}\n", self.prompt, self.options[i])
    }
}

#[derive(Clone, Debug)]
pub struct TaskData {
    pub name: &'static str,
    pub about: &'static str,
    pub train: Vec<Example>,
    pub test: Vec<Example>,
}

/// The latent world all tasks draw from.
struct World {
    categories: Vec<(&'static str, Vec<String>)>,
    tools: Vec<(String, String)>,   // tool -> action
    moods: Vec<(String, String)>,   // event -> reaction
    sizes: Vec<String>,             // total order, sizes[i] < sizes[i+1]
}

fn words(prefix: &str, n: usize, rng: &mut Rng) -> Vec<String> {
    const C: [&str; 10] = ["k", "t", "s", "m", "n", "r", "v", "z", "p", "g"];
    const V: [&str; 5] = ["a", "e", "i", "o", "u"];
    let mut out = Vec::new();
    let mut seen = std::collections::HashSet::new();
    while out.len() < n {
        let mut w = String::from(prefix);
        for _ in 0..2 {
            w.push_str(rng.choice::<&str>(&C[..]));
            w.push_str(rng.choice::<&str>(&V[..]));
        }
        if seen.insert(w.clone()) {
            out.push(w);
        }
    }
    out
}

impl World {
    fn new(seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0x77aa);
        let cat_names: [&'static str; 4] = ["animal", "plant", "metal", "liquid"];
        let categories = cat_names
            .iter()
            .map(|&c| (c, words("", 12, &mut rng)))
            .collect();
        let tool_names = words("t", 10, &mut rng);
        let action_names = words("a", 10, &mut rng);
        let tools = tool_names.into_iter().zip(action_names).collect();
        let events = words("e", 10, &mut rng);
        let reactions = words("r", 10, &mut rng);
        let moods = events.into_iter().zip(reactions).collect();
        let sizes = words("s", 8, &mut rng);
        World { categories, tools, moods, sizes }
    }

    fn random_member(&self, rng: &mut Rng) -> (usize, &str) {
        let ci = rng.below(self.categories.len());
        let m = rng.choice(&self.categories[ci].1);
        (ci, m)
    }
}

fn gen_examples<F>(n: usize, seed: u64, mut f: F) -> Vec<Example>
where
    F: FnMut(&mut Rng) -> Example,
{
    let mut rng = Rng::new(seed);
    (0..n).map(|_| f(&mut rng)).collect()
}

/// Shuffle option order (gold index tracks), so answer position is uniform.
fn shuffled(rng: &mut Rng, prompt: String, gold_text: String, distractors: Vec<String>) -> Example {
    let mut options = vec![gold_text];
    options.extend(distractors);
    let mut order: Vec<usize> = (0..options.len()).collect();
    rng.shuffle(&mut order);
    let gold = order.iter().position(|&i| i == 0).unwrap();
    let options = order.iter().map(|&i| options[i].clone()).collect();
    Example { prompt, options, gold }
}

fn boolq_like(world: &World, rng: &mut Rng) -> Example {
    let (ci, m) = world.random_member(rng);
    let truthy = rng.below(2) == 1;
    let cat = if truthy {
        world.categories[ci].0
    } else {
        let mut other = rng.below(world.categories.len());
        while other == ci {
            other = rng.below(world.categories.len());
        }
        world.categories[other].0
    };
    let gold = if truthy { "yes" } else { "no" };
    let other = if truthy { "no" } else { "yes" };
    Example {
        prompt: format!("question: is {m} a kind of {cat} ?"),
        options: vec![gold.into(), other.into()],
        gold: 0,
    }
    // note: yes/no kept in fixed positions like BoolQ's binary format
}

fn piqa_like(world: &World, rng: &mut Rng) -> Example {
    let (tool, action) = rng.choice(&world.tools).clone();
    let (_, wrong) = rng.choice(&world.tools).clone();
    if wrong == action {
        return piqa_like(world, rng);
    }
    shuffled(rng, format!("goal: use the {tool} . how ?"), action, vec![wrong])
}

fn siqa_like(world: &World, rng: &mut Rng) -> Example {
    let (event, reaction) = rng.choice(&world.moods).clone();
    let (_, wrong1) = rng.choice(&world.moods).clone();
    let (_, wrong2) = rng.choice(&world.moods).clone();
    if wrong1 == reaction || wrong2 == reaction {
        return siqa_like(world, rng);
    }
    shuffled(
        rng,
        format!("after the {event} , how does mara feel ?"),
        reaction,
        vec![wrong1, wrong2],
    )
}

fn hellaswag_like(world: &World, rng: &mut Rng) -> Example {
    // Continuation: deterministic successor rule over the size chain.
    let i = rng.below(world.sizes.len() - 1);
    let a = world.sizes[i].clone();
    let correct = world.sizes[i + 1].clone();
    let wrong = world.sizes[(i + 2 + rng.below(world.sizes.len() - 2)) % world.sizes.len()].clone();
    if wrong == correct {
        return hellaswag_like(world, rng);
    }
    shuffled(rng, format!("the sequence goes {a} then"), correct, vec![wrong])
}

fn winogrande_like(world: &World, rng: &mut Rng) -> Example {
    // Agreement/copy: the blank refers back to the opener.
    let (_, a) = world.random_member(rng);
    let (_, b) = world.random_member(rng);
    if a == b {
        return winogrande_like(world, rng);
    }
    let (tool, _) = rng.choice(&world.tools).clone();
    shuffled(
        rng,
        format!("the {a} took the {tool} from the {b} because _ wanted it . _ is the"),
        a.to_string(),
        vec![b.to_string()],
    )
}

fn arc_easy_like(world: &World, rng: &mut Rng) -> Example {
    let (ci, m) = world.random_member(rng);
    let gold = world.categories[ci].0.to_string();
    let mut other = rng.below(world.categories.len());
    while other == ci {
        other = rng.below(world.categories.len());
    }
    shuffled(
        rng,
        format!("science: what kind of thing is {m} ?"),
        gold,
        vec![world.categories[other].0.to_string()],
    )
}

fn arc_challenge_like(world: &World, rng: &mut Rng) -> Example {
    // Composition: category of BOTH mentioned items (must match).
    let ci = rng.below(world.categories.len());
    let m1 = rng.choice(&world.categories[ci].1).clone();
    let m2 = rng.choice(&world.categories[ci].1).clone();
    let gold = world.categories[ci].0.to_string();
    let distractors: Vec<String> = (0..world.categories.len())
        .filter(|&j| j != ci)
        .map(|j| world.categories[j].0.to_string())
        .collect();
    shuffled(
        rng,
        format!("science: {m1} and {m2} are both a kind of ?"),
        gold,
        distractors,
    )
}

fn obqa_like(world: &World, rng: &mut Rng) -> Example {
    // Two-hop transitivity over the size order.
    let n = world.sizes.len();
    let i = rng.below(n - 2);
    let (a, b, c) = (&world.sizes[i], &world.sizes[i + 1], &world.sizes[i + 2]);
    let flip = rng.below(2) == 1;
    let (x, z, gold) = if flip { (c, a, "yes") } else { (a, c, "no") };
    Example {
        prompt: format!(
            "facts: {b} is bigger than {a} . {c} is bigger than {b} . question: is {x} bigger than {z} ?"
        ),
        options: vec![gold.into(), if flip { "no".into() } else { "yes".into() }],
        gold: 0,
    }
}

/// Build all eight tasks with Table-4-proportional (scaled) split sizes.
pub fn all_tasks(seed: u64, scale: usize) -> Vec<TaskData> {
    let world = World::new(seed);
    // (name, about, train_n, test_n) — n scaled down from Table 4 by `scale`.
    let specs: [(&'static str, &'static str, usize, usize); 8] = [
        ("boolq", "naturally occurring yes/no questions", 94, 33),
        ("piqa", "physical commonsense with two solutions", 161, 18),
        ("siqa", "social implications", 334, 20),
        ("hellaswag", "commonsense NLI continuations", 399, 100),
        ("winogrande", "fill-in-the-blank binary", 404, 13),
        ("arc_e", "easy science questions", 23, 24),
        ("arc_c", "challenge science questions", 11, 12),
        ("obqa", "multi-step reasoning", 50, 5),
    ];
    specs
        .iter()
        .enumerate()
        .map(|(i, &(name, about, tr, te))| {
            let gen: fn(&World, &mut Rng) -> Example = match name {
                "boolq" => boolq_like,
                "piqa" => piqa_like,
                "siqa" => siqa_like,
                "hellaswag" => hellaswag_like,
                "winogrande" => winogrande_like,
                "arc_e" => arc_easy_like,
                "arc_c" => arc_challenge_like,
                _ => obqa_like,
            };
            let train = gen_examples(tr * scale, seed ^ (i as u64 * 1000 + 1), |r| gen(&world, r));
            let test = gen_examples(te * scale, seed ^ (i as u64 * 1000 + 2), |r| gen(&world, r));
            TaskData { name, about, train, test }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eight_tasks_with_splits() {
        let tasks = all_tasks(42, 1);
        assert_eq!(tasks.len(), 8);
        for t in &tasks {
            assert!(!t.train.is_empty() && !t.test.is_empty(), "{}", t.name);
            for e in t.train.iter().chain(&t.test) {
                assert!(e.gold < e.options.len());
                assert!(e.options.len() >= 2);
            }
        }
    }

    #[test]
    fn deterministic() {
        let a = all_tasks(42, 1);
        let b = all_tasks(42, 1);
        assert_eq!(a[3].train[0].prompt, b[3].train[0].prompt);
        assert_eq!(a[3].train[0].gold, b[3].train[0].gold);
    }

    #[test]
    fn option_positions_not_degenerate() {
        // In shuffled tasks the gold index should land on both positions.
        let tasks = all_tasks(7, 2);
        let piqa = &tasks[1];
        let golds: std::collections::HashSet<usize> =
            piqa.train.iter().map(|e| e.gold).collect();
        assert!(golds.len() > 1, "gold always at same position");
    }

    #[test]
    fn obqa_transitivity_consistent() {
        let tasks = all_tasks(9, 1);
        for e in &tasks[7].train {
            // gold option always at index 0 by construction; yes/no coherent
            assert!(e.options[0] == "yes" || e.options[0] == "no");
            assert_ne!(e.options[0], e.options[1]);
        }
    }

    #[test]
    fn train_text_contains_answer() {
        let tasks = all_tasks(1, 1);
        let e = &tasks[0].train[0];
        assert!(e.train_text().contains("answer:"));
        assert!(e.train_text().contains(&e.options[e.gold]));
    }
}
