//! Synthetic "audio"→transcript pairs for the whisper-like model (§4.4).
//!
//! Each token of a structured random transcript is rendered to
//! `FRAMES_PER_TOKEN` continuous feature frames via a per-token signature
//! bank (the stand-in for a log-mel spectrogram), plus Gaussian noise.
//! The seq2seq model learns to invert the rendering — after which CLOVER's
//! training-free encoder pruning can be compared against vanilla pruning
//! on token error rate, matching the paper's Whisper experiment shape.

use crate::tensor::Tensor;
use crate::util::rng::Rng;

pub const FRAMES_PER_TOKEN: usize = 2;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
/// Tokens 0..=2 reserved (pad/bos/eos); content tokens start here.
pub const FIRST_CONTENT: i32 = 3;

/// Signature bank mapping tokens to feature frames.
pub struct SignalRenderer {
    vocab: usize,
    feat_dim: usize,
    /// [vocab][FRAMES_PER_TOKEN][feat_dim]
    signatures: Vec<Vec<Vec<f32>>>,
    noise: f32,
}

impl SignalRenderer {
    pub fn new(vocab: usize, feat_dim: usize, noise: f32, seed: u64) -> Self {
        let mut rng = Rng::new(seed ^ 0xfeed);
        let signatures = (0..vocab)
            .map(|_| {
                (0..FRAMES_PER_TOKEN)
                    .map(|_| rng.normal_vec(feat_dim, 1.0))
                    .collect()
            })
            .collect();
        Self { vocab, feat_dim, signatures, noise }
    }

    /// Structured random transcript of exactly `n` content tokens:
    /// a 2nd-order pattern (each token depends on the previous) so the
    /// decoder LM has something to model beyond the acoustics.
    pub fn transcript(&self, rng: &mut Rng, n: usize) -> Vec<i32> {
        let content = (self.vocab - FIRST_CONTENT as usize) as i32;
        let mut t = Vec::with_capacity(n);
        let mut prev = rng.below(content as usize) as i32;
        for _ in 0..n {
            t.push(FIRST_CONTENT + prev);
            // biased walk: mostly +1 mod content, sometimes random jump
            prev = if rng.uniform() < 0.7 {
                (prev + 1) % content
            } else {
                rng.below(content as usize) as i32
            };
        }
        t
    }

    /// Render a transcript to feature frames [n*FRAMES_PER_TOKEN, feat_dim].
    pub fn render(&self, rng: &mut Rng, transcript: &[i32]) -> Tensor {
        let rows = transcript.len() * FRAMES_PER_TOKEN;
        let mut data = Vec::with_capacity(rows * self.feat_dim);
        for &tok in transcript {
            for f in 0..FRAMES_PER_TOKEN {
                for d in 0..self.feat_dim {
                    let sig = self.signatures[tok as usize][f][d];
                    data.push(sig + rng.normal() as f32 * self.noise);
                }
            }
        }
        Tensor::new(vec![rows, self.feat_dim], data)
    }

    /// One (feats, decoder_in, decoder_target) example with padding to
    /// (src_len, tgt_len).
    pub fn example(
        &self,
        rng: &mut Rng,
        src_len: usize,
        tgt_len: usize,
    ) -> (Tensor, Vec<i32>, Vec<i32>) {
        let n_tok = (src_len / FRAMES_PER_TOKEN).min(tgt_len - 1);
        let transcript = self.transcript(rng, n_tok);
        let feats_raw = self.render(rng, &transcript);
        // pad frames to src_len
        let mut feats = Tensor::zeros(&[src_len, self.feat_dim]);
        let copy_rows = feats_raw.shape()[0].min(src_len);
        feats.data_mut()[..copy_rows * self.feat_dim]
            .copy_from_slice(&feats_raw.data()[..copy_rows * self.feat_dim]);
        // decoder input: BOS + transcript (padded); target: transcript + EOS
        let mut dec_in = vec![0i32; tgt_len];
        let mut dec_tgt = vec![0i32; tgt_len];
        dec_in[0] = BOS;
        for (i, &t) in transcript.iter().enumerate() {
            if i + 1 < tgt_len {
                dec_in[i + 1] = t;
            }
            dec_tgt[i] = t;
        }
        if transcript.len() < tgt_len {
            dec_tgt[transcript.len()] = EOS;
        }
        (feats, dec_in, dec_tgt)
    }

    /// Batched examples: (feats [B,S,F], dec_in [B,T], dec_tgt [B,T]).
    pub fn batch(
        &self,
        rng: &mut Rng,
        b: usize,
        src_len: usize,
        tgt_len: usize,
    ) -> (Tensor, Vec<i32>, Vec<i32>) {
        let mut feats = Vec::new();
        let mut ins = Vec::new();
        let mut tgts = Vec::new();
        for _ in 0..b {
            let (f, i, t) = self.example(rng, src_len, tgt_len);
            feats.push(f);
            ins.extend(i);
            tgts.extend(t);
        }
        (Tensor::stack(&feats).unwrap(), ins, tgts)
    }

    pub fn feat_dim(&self) -> usize {
        self.feat_dim
    }
}

/// Token error rate between predicted and gold target sequences, counting
/// only positions up to (and including) gold EOS.
pub fn token_error_rate(pred: &[i32], gold: &[i32]) -> f64 {
    let mut errs = 0usize;
    let mut total = 0usize;
    for (p, g) in pred.iter().zip(gold.iter()) {
        total += 1;
        if p != g {
            errs += 1;
        }
        if *g == EOS {
            break;
        }
    }
    if total == 0 {
        0.0
    } else {
        errs as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_padding() {
        let r = SignalRenderer::new(64, 16, 0.05, 0);
        let mut rng = Rng::new(1);
        let (feats, dec_in, dec_tgt) = r.example(&mut rng, 96, 48);
        assert_eq!(feats.shape(), &[96, 16]);
        assert_eq!(dec_in.len(), 48);
        assert_eq!(dec_tgt.len(), 48);
        assert_eq!(dec_in[0], BOS);
        // shifted alignment: dec_in[i+1] == dec_tgt[i] for content positions
        for i in 0..40 {
            if dec_tgt[i] >= FIRST_CONTENT {
                assert_eq!(dec_in[i + 1], dec_tgt[i]);
            }
        }
    }

    #[test]
    fn deterministic_rendering() {
        let r = SignalRenderer::new(64, 16, 0.05, 7);
        let a = r.render(&mut Rng::new(3), &[5, 6, 7]);
        let b = r.render(&mut Rng::new(3), &[5, 6, 7]);
        assert_eq!(a, b);
    }

    #[test]
    fn signatures_distinguishable() {
        let r = SignalRenderer::new(64, 16, 0.0, 7);
        let a = r.render(&mut Rng::new(0), &[5]);
        let b = r.render(&mut Rng::new(0), &[6]);
        assert!(a.max_abs_diff(&b) > 0.5);
    }

    #[test]
    fn ter_cases() {
        assert_eq!(token_error_rate(&[1, 2], &[1, 2]), 0.0);
        assert_eq!(token_error_rate(&[9, 2], &[1, 2]), 0.5);
        // stops at EOS
        let t = token_error_rate(&[5, EOS, 0, 0], &[5, EOS, 9, 9]);
        assert_eq!(t, 0.0);
    }

    #[test]
    fn batch_shapes() {
        let r = SignalRenderer::new(64, 16, 0.05, 0);
        let mut rng = Rng::new(2);
        let (f, i, t) = r.batch(&mut rng, 4, 96, 48);
        assert_eq!(f.shape(), &[4, 96, 16]);
        assert_eq!(i.len(), 4 * 48);
        assert_eq!(t.len(), 4 * 48);
    }
}
