//! Token-stream batching for LM training and evaluation.
//!
//! A [`TokenStream`] holds one long tokenized corpus plus a train/valid
//! split; [`BatchIter`] yields `[B, T+1]` windows (inputs `[:, :T]`,
//! targets `[:, 1:]` are sliced by the caller) sampled at random offsets —
//! the nanoGPT recipe the paper's Table-1 fine-tuning follows.

use crate::tensor::TensorI;
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct TokenStream {
    train: Vec<i32>,
    valid: Vec<i32>,
}

impl TokenStream {
    /// Split a token sequence into train/valid by `valid_frac` at the tail.
    pub fn new(tokens: Vec<i32>, valid_frac: f64) -> Self {
        assert!((0.0..1.0).contains(&valid_frac));
        let n_valid = ((tokens.len() as f64) * valid_frac) as usize;
        let split = tokens.len() - n_valid;
        let (train, valid) = tokens.split_at(split);
        Self { train: train.to_vec(), valid: valid.to_vec() }
    }

    pub fn train_len(&self) -> usize {
        self.train.len()
    }

    pub fn valid_len(&self) -> usize {
        self.valid.len()
    }

    fn windows(data: &[i32], rng: &mut Rng, b: usize, t: usize) -> (TensorI, TensorI) {
        assert!(data.len() > t + 1, "stream too short: {} <= {}", data.len(), t + 1);
        let mut inputs = Vec::with_capacity(b * t);
        let mut targets = Vec::with_capacity(b * t);
        for _ in 0..b {
            let start = rng.below(data.len() - t - 1);
            inputs.extend_from_slice(&data[start..start + t]);
            targets.extend_from_slice(&data[start + 1..start + t + 1]);
        }
        (TensorI::new(vec![b, t], inputs), TensorI::new(vec![b, t], targets))
    }

    /// Random training batch: (inputs [B,T], targets [B,T]).
    pub fn train_batch(&self, rng: &mut Rng, b: usize, t: usize) -> (TensorI, TensorI) {
        Self::windows(&self.train, rng, b, t)
    }

    /// Random validation batch.
    pub fn valid_batch(&self, rng: &mut Rng, b: usize, t: usize) -> (TensorI, TensorI) {
        Self::windows(&self.valid, rng, b, t)
    }

    /// Deterministic sequential validation batches covering the split
    /// (for reproducible perplexity numbers).
    pub fn valid_batches_seq(&self, b: usize, t: usize, max_batches: usize) -> Vec<(TensorI, TensorI)> {
        let mut out = Vec::new();
        let stride = t;
        let mut pos = 0usize;
        'outer: for _ in 0..max_batches {
            let mut inputs = Vec::with_capacity(b * t);
            let mut targets = Vec::with_capacity(b * t);
            for _ in 0..b {
                if pos + t + 1 > self.valid.len() {
                    break 'outer;
                }
                inputs.extend_from_slice(&self.valid[pos..pos + t]);
                targets.extend_from_slice(&self.valid[pos + 1..pos + t + 1]);
                pos += stride;
            }
            out.push((TensorI::new(vec![b, t], inputs), TensorI::new(vec![b, t], targets)));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stream() -> TokenStream {
        TokenStream::new((0..1000).map(|x| (x % 50) as i32).collect(), 0.2)
    }

    #[test]
    fn split_sizes() {
        let s = stream();
        assert_eq!(s.train_len(), 800);
        assert_eq!(s.valid_len(), 200);
    }

    #[test]
    fn targets_shifted_by_one() {
        let s = stream();
        let mut rng = Rng::new(0);
        let (i, t) = s.train_batch(&mut rng, 4, 16);
        assert_eq!(i.shape(), &[4, 16]);
        for row in 0..4 {
            for col in 0..15 {
                assert_eq!(i.data()[row * 16 + col + 1], t.data()[row * 16 + col]);
            }
        }
    }

    #[test]
    fn seq_valid_batches_cover_and_stop() {
        let s = stream();
        let batches = s.valid_batches_seq(2, 16, 100);
        // 200 tokens / 16 stride = 12 windows = 6 batches of 2
        assert!(batches.len() >= 5 && batches.len() <= 6, "{}", batches.len());
        // deterministic
        let again = s.valid_batches_seq(2, 16, 100);
        assert_eq!(batches[0].0, again[0].0);
    }

    #[test]
    #[should_panic(expected = "stream too short")]
    fn short_stream_panics() {
        let s = TokenStream::new(vec![1, 2, 3], 0.0);
        s.train_batch(&mut Rng::new(0), 1, 16);
    }
}
