//! Report rendering: aligned text tables (stdout) + CSV files under
//! `reports/` — every experiment runner emits both, so the paper tables can
//! be eyeballed and diffed.

use anyhow::Result;
use std::path::Path;

#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
    }

    /// Aligned text rendering.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells.iter().zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// CSV with proper quoting.
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') || s.contains('\n') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Print to stdout and save CSV under `reports/<slug>.csv`.
    pub fn emit(&self, slug: &str) -> Result<()> {
        println!("{}", self.render());
        let dir = Path::new("reports");
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{slug}.csv")), self.to_csv())?;
        Ok(())
    }
}

/// Format helpers shared by the experiment runners.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn f1pct(x: f64) -> String {
    format!("{:.1}", 100.0 * x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_aligns() {
        let mut t = Table::new("T", &["a", "long_header"]);
        t.row(vec!["1".into(), "2".into()]);
        let r = t.render();
        assert!(r.contains("long_header"));
        assert!(r.lines().count() >= 4);
    }

    #[test]
    fn csv_quoting() {
        let mut t = Table::new("T", &["x"]);
        t.row(vec!["a,b\"c".into()]);
        assert!(t.to_csv().contains("\"a,b\"\"c\""));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
