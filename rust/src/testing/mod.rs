//! Minimal in-repo property-testing harness.
//!
//! The vendored crate set has no `proptest`/`quickcheck`, so this provides
//! the 10% we need: run a predicate over many deterministically-seeded
//! random cases and report the *failing seed* so a regression can be
//! replayed as a one-liner.  Used throughout `#[cfg(test)]` modules for
//! the linalg / clover / tokenizer / serve invariants.

use crate::util::rng::Rng;

/// Construct the PJRT runtime for an integration test, or skip (`None`)
/// when no live backend is available: the `xla` dependency is the vendored
/// build stub, or the AOT artifacts have not been exported yet (`make
/// artifacts`).  Tests that decode/train through HLO guard themselves with
/// this so `cargo test` is meaningful on a bare checkout and exhaustive on
/// a machine with the real bindings + artifacts.
pub fn runtime_or_skip(artifacts_dir: &str) -> Option<crate::runtime::Runtime> {
    match crate::runtime::Runtime::new(artifacts_dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP (runtime unavailable): {e:#}");
            None
        }
    }
}

/// Run `f` for `iters` seeds; panic with the failing seed + message.
///
/// `f` returns `Err(msg)` to fail a case.  Panics inside `f` are *not*
/// caught — prefer returning Err so the seed is reported.
pub fn prop<F>(name: &str, iters: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for seed in 0..iters {
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!("property `{name}` failed at seed {seed}: {msg}");
        }
    }
}

/// Assert two f32 slices are elementwise close.
pub fn assert_close(a: &[f32], b: &[f32], atol: f32, rtol: f32) -> Result<(), String> {
    if a.len() != b.len() {
        return Err(format!("length mismatch {} vs {}", a.len(), b.len()));
    }
    for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * y.abs().max(x.abs());
        if (x - y).abs() > tol {
            return Err(format!("elem {i}: {x} vs {y} (tol {tol})"));
        }
    }
    Ok(())
}

/// Relative Frobenius error ‖a-b‖/max(‖b‖, eps).
pub fn rel_err(a: &[f32], b: &[f32]) -> f32 {
    let num: f32 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f32>().sqrt();
    let den: f32 = b.iter().map(|y| y * y).sum::<f32>().sqrt().max(1e-12);
    num / den
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prop_passes() {
        prop("trivial", 10, |rng| {
            let x = rng.uniform();
            if (0.0..1.0).contains(&x) { Ok(()) } else { Err(format!("{x}")) }
        });
    }

    #[test]
    #[should_panic(expected = "seed 0")]
    fn prop_reports_seed() {
        prop("always-fails", 3, |_| Err("nope".into()));
    }

    #[test]
    fn close_and_rel() {
        assert!(assert_close(&[1.0, 2.0], &[1.0, 2.0 + 1e-7], 1e-6, 1e-6).is_ok());
        assert!(assert_close(&[1.0], &[1.1], 1e-6, 1e-6).is_err());
        assert!(rel_err(&[1.0, 0.0], &[1.0, 0.0]) < 1e-9);
    }
}
