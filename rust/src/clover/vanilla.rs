//! Vanilla (baseline) structured pruning — no orthogonalization.
//!
//! Ranks each head's existing dimensions by the product of projection
//! column norms (‖Wq·,i‖·‖Wk·,i‖ for Q-K; ‖Wv·,i‖·‖Wo i,·‖ for V-O — the
//! paper's §4.1 L2-norm baseline) and keeps the top r.  The kept columns
//! are packed into the *factorized* parameter layout with S = I, so vanilla
//! and CLOVER pruning run through the identical HLO artifacts and any
//! perplexity difference is attributable to the orthogonalization alone.

use anyhow::{Context, Result};

use crate::model::manifest::ParamSpec;
use crate::model::params::ParamSet;
use crate::tensor::Tensor;

use super::transform::Naming;

/// Per-dimension importance of one head: the norm-product curve vanilla
/// pruning sorts by (and Fig 2's orange line).
pub fn importance_qk(wq_h: &Tensor, wk_h: &Tensor) -> Vec<f32> {
    let d = wq_h.shape()[1];
    (0..d).map(|i| wq_h.col_norm(i) * wk_h.col_norm(i)).collect()
}

/// Keep the `r` highest-importance dims (indices in original order).
pub fn top_dims(importance: &[f32], r: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..importance.len()).collect();
    idx.sort_by(|&a, &b| importance[b].partial_cmp(&importance[a]).unwrap());
    let mut keep = idx[..r].to_vec();
    keep.sort_unstable();
    keep
}

fn gather_cols(w: &Tensor, dims: &[usize]) -> Tensor {
    let (m, _) = (w.shape()[0], w.shape()[1]);
    let mut out = Vec::with_capacity(m * dims.len());
    for i in 0..m {
        for &j in dims {
            out.push(w.at2(i, j));
        }
    }
    Tensor::new(vec![m, dims.len()], out)
}

/// Vanilla-prune a dense parameter set into the factorized layout at the
/// rank fixed by `fac_spec`.
pub fn vanilla_prune(
    dense: &ParamSet,
    fac_spec: &ParamSpec,
    n_heads: usize,
    naming: &Naming,
) -> Result<ParamSet> {
    let wq = dense.get(naming.wq)?;
    let wk = dense.get(naming.wk)?;
    let wv = dense.get(naming.wv)?;
    let wo = dense.get(naming.wo)?;
    let n_layers = wq.shape()[0];
    let d_model = wq.shape()[1];
    let dh = d_model / n_heads;
    let r = fac_spec
        .iter()
        .find(|(n, _)| n == naming.u_qk)
        .context("fac spec missing u_qk")?
        .1[3];

    let mut out = ParamSet::zeros(fac_spec);
    for (name, _) in fac_spec {
        let is_factor = [
            naming.u_qk, naming.s_qk, naming.v_qk,
            naming.u_vo, naming.s_vo, naming.v_vo,
        ]
        .contains(&name.as_str());
        if !is_factor {
            out.set(name, dense.get(name)?.clone())?;
        }
    }

    let eye = {
        let mut t = Tensor::zeros(&[r, r]);
        for i in 0..r {
            t.data_mut()[i * r + i] = 1.0;
        }
        t
    };

    let mut u_qk = Vec::new();
    let mut v_qk = Vec::new();
    let mut u_vo = Vec::new();
    let mut v_vo = Vec::new();
    let mut ss = Vec::new();
    for l in 0..n_layers {
        let (wq_l, wk_l, wv_l, wo_l) =
            (wq.index0(l), wk.index0(l), wv.index0(l), wo.index0(l));
        for h in 0..n_heads {
            let q_h = wq_l.cols(h * dh, (h + 1) * dh);
            let k_h = wk_l.cols(h * dh, (h + 1) * dh);
            let keep = top_dims(&importance_qk(&q_h, &k_h), r);
            u_qk.push(gather_cols(&q_h, &keep));
            v_qk.push(gather_cols(&k_h, &keep));
            let v_h = wv_l.cols(h * dh, (h + 1) * dh);
            let o_h = wo_l.rows(h * dh, (h + 1) * dh).transpose2(); // D×d
            let keep_vo = top_dims(&importance_qk(&v_h, &o_h), r);
            u_vo.push(gather_cols(&v_h, &keep_vo));
            v_vo.push(gather_cols(&o_h, &keep_vo));
            ss.push(eye.clone());
        }
    }
    let stack4 = |parts: &[Tensor], d2: usize, d3: usize| -> Result<Tensor> {
        Ok(Tensor::stack(parts)?.reshape(&[n_layers, n_heads, d2, d3])?)
    };
    out.set(naming.u_qk, stack4(&u_qk, d_model, r)?)?;
    out.set(naming.v_qk, stack4(&v_qk, d_model, r)?)?;
    out.set(naming.u_vo, stack4(&u_vo, d_model, r)?)?;
    out.set(naming.v_vo, stack4(&v_vo, d_model, r)?)?;
    out.set(naming.s_qk, stack4(&ss, r, r)?)?;
    out.set(naming.s_vo, stack4(&ss, r, r)?)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::clover::transform::DECODER_NAMING;
    use crate::linalg::{matmul, matmul_nt};
    use crate::testing::rel_err;
    use crate::util::rng::Rng;

    #[test]
    fn top_dims_picks_largest() {
        let imp = vec![0.1, 5.0, 0.3, 2.0];
        assert_eq!(top_dims(&imp, 2), vec![1, 3]);
        assert_eq!(top_dims(&imp, 4), vec![0, 1, 2, 3]);
    }

    #[test]
    fn full_rank_vanilla_is_lossless() {
        // keeping all dims reproduces W_QK exactly
        let mut rng = Rng::new(2);
        let spec: ParamSpec = vec![
            ("wq".into(), vec![1, 8, 8]),
            ("wk".into(), vec![1, 8, 8]),
            ("wv".into(), vec![1, 8, 8]),
            ("wo".into(), vec![1, 8, 8]),
        ];
        let dense = ParamSet::gaussian(&spec, &mut rng, 0.5);
        let fac_spec: ParamSpec = vec![
            ("u_qk".into(), vec![1, 2, 8, 4]),
            ("s_qk".into(), vec![1, 2, 4, 4]),
            ("v_qk".into(), vec![1, 2, 8, 4]),
            ("u_vo".into(), vec![1, 2, 8, 4]),
            ("s_vo".into(), vec![1, 2, 4, 4]),
            ("v_vo".into(), vec![1, 2, 8, 4]),
        ];
        let fac = vanilla_prune(&dense, &fac_spec, 2, &DECODER_NAMING).unwrap();
        let wq = dense.get("wq").unwrap().index0(0).cols(0, 4);
        let wk = dense.get("wk").unwrap().index0(0).cols(0, 4);
        let want = matmul_nt(&wq, &wk);
        let u = fac.get("u_qk").unwrap();
        let v = fac.get("v_qk").unwrap();
        let u0 = Tensor::new(vec![8, 4], u.data()[..32].to_vec());
        let v0 = Tensor::new(vec![8, 4], v.data()[..32].to_vec());
        let got = matmul(&u0, &v0.transpose2());
        assert!(rel_err(got.data(), want.data()) < 1e-5);
    }

    #[test]
    fn pruned_importance_is_subset() {
        // With r < d the kept columns are exactly the top-importance ones.
        let mut rng = Rng::new(5);
        let mut q = Tensor::new(vec![8, 4], rng.normal_vec(32, 1.0));
        // make column 2 huge so it must be kept
        for i in 0..8 {
            q.set2(i, 2, 10.0);
        }
        let k = Tensor::new(vec![8, 4], rng.normal_vec(32, 1.0));
        let keep = top_dims(&importance_qk(&q, &k), 2);
        assert!(keep.contains(&2));
    }
}
