//! CLOVER: cross-layer orthogonal vectors — transform, pruning, analyses.
//!
//! The paper's §3 algorithm ([`transform::clover_transform`]), the vanilla
//! baseline it is compared against ([`vanilla::vanilla_prune`]), pruning
//! policies ([`prune`]), and the measurement passes behind Figures 2/4/5/6
//! ([`analysis`]).

pub mod analysis;
pub mod prune;
pub mod transform;
pub mod vanilla;

pub use analysis::{delta_spectrum, intruder_count, projection_shares, SpectrumRow};
pub use prune::{achieved_ratio, rank_for_ratio, threshold_prune_s};
pub use transform::{clover_transform, factorize_pair, merge_s, Naming, Spectra,
                    DECODER_NAMING, ENCODER_NAMING};
pub use vanilla::vanilla_prune;
