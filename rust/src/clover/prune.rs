//! Pruning policies on top of the CLOVER / vanilla factorizations.
//!
//! * [`rank_for_ratio`] — Table-1-style uniform structured pruning: every
//!   head keeps the same rank, chosen from the artifact rank grid.
//! * [`threshold_prune_s`] — §4.4-style training-free pruning: zero every
//!   singular value below a magnitude threshold (per-head variable rank,
//!   expressed by zeroing S entries so the full-rank artifact stays
//!   shape-compatible); reports the achieved pruning ratio.
//! * [`energy_rank`] — per-head rank needed to keep a target energy share.

use anyhow::Result;

use crate::model::params::ParamSet;

/// Uniform rank for a pruning ratio, snapped to the artifact grid.
///
/// ratio 0.25 with d=32 → ideal rank 24; picks the largest grid rank ≤
/// ideal (falling back to the smallest available).
pub fn rank_for_ratio(d_head: usize, ratio: f64, grid: &[usize]) -> usize {
    let ideal = ((d_head as f64) * (1.0 - ratio)).round() as usize;
    let mut best: Option<usize> = None;
    for &r in grid {
        if r <= ideal && r >= 1 {
            best = Some(best.map_or(r, |b: usize| b.max(r)));
        }
    }
    best.unwrap_or_else(|| grid.iter().copied().min().unwrap_or(1))
}

/// Fraction of parameters removed when each head keeps rank r of d.
pub fn achieved_ratio(d_head: usize, r: usize) -> f64 {
    1.0 - (r as f64) / (d_head as f64)
}

/// Zero out singular values `|s| <= eps` in a stacked S tensor
/// `[L, H, r, r]`.  Returns (pruned, total) diagonal entries.
pub fn threshold_prune_s(fac: &mut ParamSet, s_name: &str, eps: f32) -> Result<(usize, usize)> {
    let s = fac.get(s_name)?.clone();
    let shape = s.shape().to_vec();
    let (l, h, r) = (shape[0], shape[1], shape[2]);
    let mut data = s.into_data();
    let mut pruned = 0usize;
    for li in 0..l {
        for hi in 0..h {
            let base = (li * h + hi) * r * r;
            for i in 0..r {
                let idx = base + i * r + i;
                if data[idx].abs() <= eps {
                    if data[idx] != 0.0 {
                        pruned += 1;
                    } else {
                        pruned += 1; // already zero counts as pruned capacity
                    }
                    data[idx] = 0.0;
                }
            }
        }
    }
    let total = l * h * r;
    fac.set(s_name, crate::tensor::Tensor::new(shape, data))?;
    Ok((pruned, total))
}

/// Smallest rank keeping `target` fraction of Σσ² for one head's spectrum.
pub fn energy_rank(s: &[f32], target: f32) -> usize {
    let total: f32 = s.iter().map(|x| x * x).sum();
    if total <= 0.0 {
        return 0;
    }
    let mut acc = 0.0f32;
    for (i, &x) in s.iter().enumerate() {
        acc += x * x;
        if acc >= target * total {
            return i + 1;
        }
    }
    s.len()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::ParamSpec;
    use crate::tensor::Tensor;

    #[test]
    fn rank_snapping() {
        let grid = [16, 14, 12, 10, 8, 6, 4, 2];
        assert_eq!(rank_for_ratio(16, 0.0, &grid), 16);
        assert_eq!(rank_for_ratio(16, 0.25, &grid), 12);
        assert_eq!(rank_for_ratio(16, 0.5, &grid), 8);
        assert_eq!(rank_for_ratio(16, 0.75, &grid), 4);
        assert_eq!(rank_for_ratio(16, 0.99, &grid), 2);
    }

    #[test]
    fn achieved_ratio_sane() {
        assert_eq!(achieved_ratio(16, 16), 0.0);
        assert_eq!(achieved_ratio(16, 8), 0.5);
    }

    #[test]
    fn threshold_zeroes_small() {
        let spec: ParamSpec = vec![("s_qk".into(), vec![1, 1, 3, 3])];
        let mut p = ParamSet::zeros(&spec);
        let mut t = Tensor::zeros(&[1, 1, 3, 3]);
        t.data_mut()[0] = 5.0; // (0,0)
        t.data_mut()[4] = 0.01; // (1,1)
        t.data_mut()[8] = 0.5; // (2,2)
        p.set("s_qk", t).unwrap();
        let (pruned, total) = threshold_prune_s(&mut p, "s_qk", 0.1).unwrap();
        assert_eq!(total, 3);
        assert_eq!(pruned, 1);
        let s = p.get("s_qk").unwrap();
        assert_eq!(s.data()[4], 0.0);
        assert_eq!(s.data()[0], 5.0);
        assert_eq!(s.data()[8], 0.5);
    }

    #[test]
    fn energy_rank_monotone() {
        let s = vec![4.0, 2.0, 1.0, 0.1];
        assert!(energy_rank(&s, 0.5) <= energy_rank(&s, 0.9));
        assert_eq!(energy_rank(&s, 1.0), 4);
        assert_eq!(energy_rank(&[0.0, 0.0], 0.9), 0);
    }
}
