//! The CLOVER transform: cross-layer head-wise orthogonalization.
//!
//! For each attention head h with dense projections `Wq_h, Wk_h ∈ R^{D×d}`
//! (and `Wv_h ∈ R^{D×d}`, `Wo_h ∈ R^{d×D}`), factorize the cross-layer
//! products
//!
//! ```text
//! W_QK^h = Wq_h Wk_hᵀ = U_qk S_qk V_qkᵀ      (rank ≤ d)
//! W_VO^h = Wv_h Wo_h  = U_vo S_vo V_voᵀ      (rank ≤ d)
//! ```
//!
//! without ever materializing the D×D products: QR-reduce both factors
//! (`Wq = Q₁R₁`, `Wk = Q₂R₂`), SVD the small d×d core `R₁R₂ᵀ = U' Σ V'ᵀ`,
//! and recover `U = Q₁U'`, `V = Q₂V'` — an O(D·d²) transform per head
//! (paper §3; the QR reduction is the standard trick for products of thin
//! matrices).
//!
//! The result plugs directly into the factorized HLO artifacts: `u_qk
//! [L,H,D,r]`, `s_qk [L,H,r,r]` (diagonal at init), `v_qk [L,H,D,r]`, and
//! the V-O triple likewise.

use anyhow::{Context, Result};

use crate::linalg::{matmul, matmul_nt, qr::qr_thin};
use crate::linalg::svd::svd;
use crate::model::manifest::ParamSpec;
use crate::model::params::ParamSet;
use crate::tensor::Tensor;

/// Orthogonalized factors of one head pair plus its singular values.
pub struct HeadFactors {
    /// D×r, orthonormal columns.
    pub u: Tensor,
    /// Singular values, length r (descending).
    pub s: Vec<f32>,
    /// D×r, orthonormal columns.
    pub v: Tensor,
}

/// Factorize a cross-layer product `A·Bᵀ` given thin factors A, B ∈ R^{D×d},
/// truncated to rank `r`.
pub fn factorize_pair(a: &Tensor, b: &Tensor, r: usize) -> HeadFactors {
    let d = a.shape()[1];
    assert_eq!(b.shape()[1], d);
    assert!(r <= d, "rank {r} > head dim {d}");
    let qa = qr_thin(a);
    let qb = qr_thin(b);
    let core = matmul_nt(&qa.r, &qb.r); // R₁·R₂ᵀ, d×d
    let dec = svd(&core);
    let u = matmul(&qa.q, &dec.u.cols(0, r));
    let v = matmul(&qb.q, &dec.vt.transpose2().cols(0, r));
    HeadFactors { u, s: dec.s[..r].to_vec(), v }
}

/// Diagonal r×r tensor from singular values.
pub fn diag(s: &[f32]) -> Tensor {
    let r = s.len();
    let mut t = Tensor::zeros(&[r, r]);
    for (i, &x) in s.iter().enumerate() {
        t.data_mut()[i * r + i] = x;
    }
    t
}

/// Slice head `h`'s column block out of a stacked projection `w [D, D]`.
fn head_cols(w: &Tensor, h: usize, dh: usize) -> Tensor {
    w.cols(h * dh, (h + 1) * dh)
}

/// Per-(layer, head) singular-value spectra, the raw material of Fig 2.
pub struct Spectra {
    /// [layer][head] -> singular values of W_QK (full, untruncated).
    pub qk: Vec<Vec<Vec<f32>>>,
    /// [layer][head] -> singular values of W_VO.
    pub vo: Vec<Vec<Vec<f32>>>,
}

/// Options naming the dense/factorized tensors (decoder vs seq2seq-encoder
/// use different prefixes).
pub struct Naming {
    pub wq: &'static str,
    pub wk: &'static str,
    pub wv: &'static str,
    pub wo: &'static str,
    pub u_qk: &'static str,
    pub s_qk: &'static str,
    pub v_qk: &'static str,
    pub u_vo: &'static str,
    pub s_vo: &'static str,
    pub v_vo: &'static str,
}

pub const DECODER_NAMING: Naming = Naming {
    wq: "wq", wk: "wk", wv: "wv", wo: "wo",
    u_qk: "u_qk", s_qk: "s_qk", v_qk: "v_qk",
    u_vo: "u_vo", s_vo: "s_vo", v_vo: "v_vo",
};

pub const ENCODER_NAMING: Naming = Naming {
    wq: "e_wq", wk: "e_wk", wv: "e_wv", wo: "e_wo",
    u_qk: "e_u_qk", s_qk: "e_s_qk", v_qk: "e_v_qk",
    u_vo: "e_u_vo", s_vo: "e_s_vo", v_vo: "e_v_vo",
};

/// Apply the CLOVER transform to a dense parameter set, producing the
/// factorized set (per `fac_spec`, which fixes rank r) plus full spectra.
///
/// Non-attention tensors are copied through unchanged.
pub fn clover_transform(
    dense: &ParamSet,
    fac_spec: &ParamSpec,
    n_heads: usize,
    naming: &Naming,
) -> Result<(ParamSet, Spectra)> {
    let wq = dense.get(naming.wq)?;
    let wk = dense.get(naming.wk)?;
    let wv = dense.get(naming.wv)?;
    let wo = dense.get(naming.wo)?;
    let n_layers = wq.shape()[0];
    let d_model = wq.shape()[1];
    let dh = d_model / n_heads;
    // rank r comes from the factorized spec
    let r = fac_spec
        .iter()
        .find(|(n, _)| n == naming.u_qk)
        .context("fac spec missing u_qk")?
        .1[3];

    let mut out = ParamSet::zeros(fac_spec);
    // Copy pass-through tensors.
    for (name, _) in fac_spec {
        let is_factor = [
            naming.u_qk, naming.s_qk, naming.v_qk,
            naming.u_vo, naming.s_vo, naming.v_vo,
            "u_ud", "s_ud", "v_ud", // filled by factorize_up_blocks
        ]
        .contains(&name.as_str());
        if !is_factor {
            out.set(name, dense.get(name)?.clone())
                .with_context(|| format!("copying {name}"))?;
        }
    }

    let mut spectra = Spectra { qk: Vec::new(), vo: Vec::new() };
    let mut u_qk = Vec::new();
    let mut s_qk = Vec::new();
    let mut v_qk = Vec::new();
    let mut u_vo = Vec::new();
    let mut s_vo = Vec::new();
    let mut v_vo = Vec::new();

    for l in 0..n_layers {
        let (wq_l, wk_l, wv_l, wo_l) =
            (wq.index0(l), wk.index0(l), wv.index0(l), wo.index0(l));
        let mut sq_layer = Vec::new();
        let mut sv_layer = Vec::new();
        for h in 0..n_heads {
            // Q-K pair.
            let a = head_cols(&wq_l, h, dh);
            let b = head_cols(&wk_l, h, dh);
            let full = factorize_pair(&a, &b, dh);
            sq_layer.push(full.s.clone());
            u_qk.push(full.u.cols(0, r));
            s_qk.push(diag(&full.s[..r]));
            v_qk.push(full.v.cols(0, r));
            // V-O pair: Wv_h [D,d] · Wo_h [d,D]; treat Wo_hᵀ as the thin B.
            let av = head_cols(&wv_l, h, dh);
            let bo = wo_l.rows(h * dh, (h + 1) * dh).transpose2(); // D×d
            let fvo = factorize_pair(&av, &bo, dh);
            sv_layer.push(fvo.s.clone());
            u_vo.push(fvo.u.cols(0, r));
            s_vo.push(diag(&fvo.s[..r]));
            v_vo.push(fvo.v.cols(0, r));
        }
        spectra.qk.push(sq_layer);
        spectra.vo.push(sv_layer);
    }

    let stack4 = |parts: &[Tensor], d2: usize, d3: usize| -> Result<Tensor> {
        Tensor::stack(parts)?.reshape(&[n_layers, n_heads, d2, d3])
    };
    out.set(naming.u_qk, stack4(&u_qk, d_model, r)?)?;
    out.set(naming.s_qk, stack4(&s_qk, r, r)?)?;
    out.set(naming.v_qk, stack4(&v_qk, d_model, r)?)?;
    out.set(naming.u_vo, stack4(&u_vo, d_model, r)?)?;
    out.set(naming.s_vo, stack4(&s_vo, r, r)?)?;
    out.set(naming.v_vo, stack4(&v_vo, d_model, r)?)?;
    Ok((out, spectra))
}

/// Factorize the MLP Up projection into `UD_BLOCK`-column blocks by
/// intra-layer SVD — the Table-2 fine-tuning configuration ("treat the 64
/// consecutive dimensions in the MLP.Up layer as a head").  Produces the
/// `u_ud [L,NB,D,K]`, `s_ud [L,NB,K,K]` (diag init), `v_ud [L,NB,K,K]`
/// tensors of the `facud` spec such that `W_up[:, blk] = U·S·Vᵀ` exactly.
pub fn factorize_up_blocks(
    dense: &ParamSet,
    facud_spec: &ParamSpec,
) -> Result<(Tensor, Tensor, Tensor)> {
    let w_up = dense.get("w_up")?;
    let (l, d, f) = (w_up.shape()[0], w_up.shape()[1], w_up.shape()[2]);
    let k = facud_spec.iter().find(|(n, _)| n == "u_ud")
        .context("facud spec missing u_ud")?.1[3];
    let nb = f / k;
    let mut us = Vec::new();
    let mut ss = Vec::new();
    let mut vs = Vec::new();
    for li in 0..l {
        let w_l = w_up.index0(li); // [D, F]
        for b in 0..nb {
            let blk = w_l.cols(b * k, (b + 1) * k); // [D, K]
            let dec = svd(&blk);
            us.push(dec.u.cols(0, k));
            ss.push(diag(&dec.s[..k]));
            vs.push(dec.vt.transpose2().cols(0, k));
        }
    }
    Ok((
        Tensor::stack(&us)?.reshape(&[l, nb, d, k])?,
        Tensor::stack(&ss)?.reshape(&[l, nb, k, k])?,
        Tensor::stack(&vs)?.reshape(&[l, nb, k, k])?,
    ))
}

/// Build the full CLOVER fine-tuning parameter set (`facud` spec): QK/VO
/// cross-layer factorization at full rank plus blockwise Up factorization.
pub fn clover_ft_params(
    dense: &ParamSet,
    facud_spec: &ParamSpec,
    n_heads: usize,
) -> Result<ParamSet> {
    let (mut fac, _) = clover_transform(dense, facud_spec, n_heads, &DECODER_NAMING)?;
    let (u_ud, s_ud, v_ud) = factorize_up_blocks(dense, facud_spec)?;
    fac.set("u_ud", u_ud)?;
    fac.set("s_ud", s_ud)?;
    fac.set("v_ud", v_ud)?;
    Ok(fac)
}

/// Merge singular values back into U (`U ← U·S`) and set S to identity —
/// the paper's "reintegrated into the model without increasing its
/// parameter count" step after pruning or fine-tuning.
pub fn merge_s(fac: &mut ParamSet, naming: &Naming) -> Result<()> {
    for (u_name, s_name) in [(naming.u_qk, naming.s_qk), (naming.u_vo, naming.s_vo)] {
        let u = fac.get(u_name)?.clone();
        let s = fac.get(s_name)?.clone();
        let (l, h, d, r) = (u.shape()[0], u.shape()[1], u.shape()[2], u.shape()[3]);
        let mut new_u = Tensor::zeros(&[l, h, d, r]);
        let mut new_s = Tensor::zeros(&[l, h, r, r]);
        for li in 0..l {
            for hi in 0..h {
                let base_u = (li * h + hi) * d * r;
                let base_s = (li * h + hi) * r * r;
                let u_blk = Tensor::new(vec![d, r], u.data()[base_u..base_u + d * r].to_vec());
                let s_blk = Tensor::new(vec![r, r], s.data()[base_s..base_s + r * r].to_vec());
                let merged = matmul(&u_blk, &s_blk);
                new_u.data_mut()[base_u..base_u + d * r].copy_from_slice(merged.data());
                for i in 0..r {
                    new_s.data_mut()[base_s + i * r + i] = 1.0;
                }
            }
        }
        fac.set(u_name, new_u)?;
        fac.set(s_name, new_s)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{prop, rel_err};
    use crate::util::rng::Rng;

    #[test]
    fn factorize_pair_exact_at_full_rank() {
        prop("U S Vᵀ == A·Bᵀ at r = d", 15, |rng| {
            let d_model = 16 + rng.below(16);
            let d = 4 + rng.below(4);
            let a = Tensor::new(vec![d_model, d], rng.normal_vec(d_model * d, 1.0));
            let b = Tensor::new(vec![d_model, d], rng.normal_vec(d_model * d, 1.0));
            let f = factorize_pair(&a, &b, d);
            let want = matmul_nt(&a, &b);
            let got = matmul(&matmul(&f.u, &diag(&f.s)), &f.v.transpose2());
            let err = rel_err(got.data(), want.data());
            if err > 1e-3 {
                return Err(format!("rel err {err}"));
            }
            Ok(())
        });
    }

    #[test]
    fn factors_orthonormal() {
        prop("CLOVER factors orthonormal", 10, |rng| {
            let a = Tensor::new(vec![24, 6], rng.normal_vec(144, 1.0));
            let b = Tensor::new(vec![24, 6], rng.normal_vec(144, 1.0));
            let f = factorize_pair(&a, &b, 6);
            let du = crate::linalg::ortho_defect(&f.u);
            let dv = crate::linalg::ortho_defect(&f.v);
            if du > 1e-3 || dv > 1e-3 {
                return Err(format!("defects {du} {dv}"));
            }
            Ok(())
        });
    }

    #[test]
    fn truncation_is_best_energy() {
        // Truncated CLOVER reconstruction error equals the energy in the
        // dropped singular values (Eckart–Young).
        let mut rng = Rng::new(4);
        let a = Tensor::new(vec![32, 8], rng.normal_vec(256, 1.0));
        let b = Tensor::new(vec![32, 8], rng.normal_vec(256, 1.0));
        let full = factorize_pair(&a, &b, 8);
        let r = 4;
        let trunc = factorize_pair(&a, &b, r);
        let want = matmul_nt(&a, &b);
        let got = matmul(&matmul(&trunc.u, &diag(&trunc.s)), &trunc.v.transpose2());
        let err2: f32 = got.data().iter().zip(want.data())
            .map(|(x, y)| (x - y) * (x - y)).sum();
        let dropped: f32 = full.s[r..].iter().map(|x| x * x).sum();
        assert!((err2 - dropped).abs() < 1e-2 * dropped.max(1.0),
                "err² {err2} vs dropped energy {dropped}");
    }

    fn dense_fixture(l: usize, _h: usize, d: usize) -> (ParamSet, ParamSpec) {
        let spec: ParamSpec = vec![
            ("tok_emb".into(), vec![8, d]),
            ("wq".into(), vec![l, d, d]),
            ("wk".into(), vec![l, d, d]),
            ("wv".into(), vec![l, d, d]),
            ("wo".into(), vec![l, d, d]),
        ];
        let mut rng = Rng::new(11);
        (ParamSet::gaussian(&spec, &mut rng, 0.3), spec)
    }

    fn fac_fixture_spec(l: usize, h: usize, d: usize, r: usize) -> ParamSpec {
        vec![
            ("tok_emb".into(), vec![8, d]),
            ("u_qk".into(), vec![l, h, d, r]),
            ("s_qk".into(), vec![l, h, r, r]),
            ("v_qk".into(), vec![l, h, d, r]),
            ("u_vo".into(), vec![l, h, d, r]),
            ("s_vo".into(), vec![l, h, r, r]),
            ("v_vo".into(), vec![l, h, d, r]),
        ]
    }

    #[test]
    fn transform_reconstructs_wqk() {
        let (l, h, d) = (2, 2, 8);
        let dh = d / h;
        let (dense, _) = dense_fixture(l, h, d);
        let fac_spec = fac_fixture_spec(l, h, d, dh);
        let (fac, spectra) = clover_transform(&dense, &fac_spec, h, &DECODER_NAMING).unwrap();
        assert_eq!(spectra.qk.len(), l);
        assert_eq!(spectra.qk[0].len(), h);
        // check W_QK reconstruction for layer 0, head 1
        let wq = dense.get("wq").unwrap().index0(0);
        let wk = dense.get("wk").unwrap().index0(0);
        let a = head_cols(&wq, 1, dh);
        let b = head_cols(&wk, 1, dh);
        let want = matmul_nt(&a, &b);
        let u = fac.get("u_qk").unwrap();
        let s = fac.get("s_qk").unwrap();
        let v = fac.get("v_qk").unwrap();
        let base_u = (0 * h + 1) * d * dh;
        let base_s = (0 * h + 1) * dh * dh;
        let u_blk = Tensor::new(vec![d, dh], u.data()[base_u..base_u + d * dh].to_vec());
        let s_blk = Tensor::new(vec![dh, dh], s.data()[base_s..base_s + dh * dh].to_vec());
        let v_blk = Tensor::new(vec![d, dh], v.data()[base_u..base_u + d * dh].to_vec());
        let got = matmul(&matmul(&u_blk, &s_blk), &v_blk.transpose2());
        assert!(rel_err(got.data(), want.data()) < 1e-3);
        // pass-through copied
        assert_eq!(fac.get("tok_emb").unwrap(), dense.get("tok_emb").unwrap());
    }

    #[test]
    fn merge_s_preserves_product() {
        let (l, h, d) = (1, 2, 8);
        let dh = d / h;
        let (dense, _) = dense_fixture(l, h, d);
        let fac_spec = fac_fixture_spec(l, h, d, dh);
        let (mut fac, _) = clover_transform(&dense, &fac_spec, h, &DECODER_NAMING).unwrap();
        let before_u = fac.get("u_qk").unwrap().clone();
        let before_s = fac.get("s_qk").unwrap().clone();
        merge_s(&mut fac, &DECODER_NAMING).unwrap();
        // S is now identity
        let s = fac.get("s_qk").unwrap();
        for li in 0..l {
            for hi in 0..h {
                let base = (li * h + hi) * dh * dh;
                for i in 0..dh {
                    for j in 0..dh {
                        let want = if i == j { 1.0 } else { 0.0 };
                        assert!((s.data()[base + i * dh + j] - want).abs() < 1e-6);
                    }
                }
            }
        }
        // U·S (old) == U (new)
        let u_blk_old = Tensor::new(vec![d, dh], before_u.data()[..d * dh].to_vec());
        let s_blk_old = Tensor::new(vec![dh, dh], before_s.data()[..dh * dh].to_vec());
        let merged = matmul(&u_blk_old, &s_blk_old);
        let u_new = fac.get("u_qk").unwrap();
        crate::testing::assert_close(&u_new.data()[..d * dh], merged.data(), 1e-5, 1e-5).unwrap();
    }
}
