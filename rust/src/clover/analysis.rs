//! Analysis passes behind Figures 2, 4, 5 and 6.
//!
//! * Fig 2 — per-head sorted importance curves: CLOVER singular values vs
//!   vanilla norm-products ([`spectra_rows`]).
//! * Fig 4 — projection of data features onto adapter directions
//!   ([`projection_shares`]): LoRA's random subspace vs PiSSA's principal
//!   subspace vs CLOVER's full orthogonal basis (±singular-value scaling).
//! * Fig 5 — singular-value spectrum of the weight update ΔW
//!   ([`delta_spectrum`]): LoRA is rank-limited, CLOVER/full-FT full-rank.
//! * Fig 6 — "intruder dimensions" ([`intruder_count`]): post-fine-tuning
//!   top singular vectors that have no counterpart in the pre-fine-tuning
//!   basis (Shuttleworth et al., 2024).

use crate::linalg::svd::svd;
use crate::linalg::matmul_tn;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// One Fig-2 row: sorted descending importance per dimension of one head.
pub struct SpectrumRow {
    pub layer: usize,
    pub head: usize,
    /// CLOVER: singular values of the cross-layer product.
    pub clover: Vec<f32>,
    /// Vanilla: sorted ‖Wq·,i‖·‖Wk·,i‖ norm products.
    pub vanilla: Vec<f32>,
}

/// Index of the first position where the CLOVER curve drops below the
/// vanilla curve and stays below — Fig 2's red intersection point.
pub fn crossover(clover: &[f32], vanilla: &[f32]) -> Option<usize> {
    let n = clover.len().min(vanilla.len());
    for i in 0..n {
        if clover[i] < vanilla[i] && clover[n - 1] <= vanilla[n - 1] {
            return Some(i);
        }
    }
    None
}

/// Mean squared projection of feature rows onto each direction (column) of
/// an orthonormal basis `u [D, k]`.  `x` is [N, D] (tokens flattened).
pub fn projection_mass(x: &Tensor, u: &Tensor) -> Vec<f32> {
    assert_eq!(x.shape()[1], u.shape()[0]);
    let n = x.shape()[0];
    let k = u.shape()[1];
    // P = Xᵀ·X (D×D) would be heavy; instead accumulate ‖X·u_k‖² per col:
    // mass_k = Σ_rows (x·u_k)² = ‖X u‖²_col.
    let xu = crate::linalg::matmul(x, u); // [N, k]
    let mut mass = vec![0.0f32; k];
    for i in 0..n {
        for j in 0..k {
            let v = xu.at2(i, j);
            mass[j] += v * v;
        }
    }
    for m in &mut mass {
        *m /= n as f32;
    }
    mass
}

/// Fig-4 shares: fraction of total feature energy captured by
/// (a) a random rank-r subspace (LoRA), (b) the top-r singular directions
/// (PiSSA), (c) all directions (CLOVER) — and (d) the share of the top-1
/// direction after singular-value scaling.
pub struct ProjectionShares {
    pub lora_r: f32,
    pub pissa_r: f32,
    pub clover_all: f32,
    pub top1_unscaled: f32,
    pub top1_scaled: f32,
}

pub fn projection_shares(
    x: &Tensor,
    u: &Tensor,
    s: &[f32],
    r: usize,
    rng: &mut Rng,
) -> ProjectionShares {
    let d = u.shape()[0];
    let mass = projection_mass(x, u); // per orthogonal direction
    let total: f32 = mass.iter().sum();
    let pissa_r: f32 = mass.iter().take(r).sum::<f32>() / total.max(1e-12);
    // LoRA: random orthonormal r-subspace (QR of a Gaussian).
    let g = Tensor::new(vec![d, r], rng.normal_vec(d * r, 1.0));
    let q = crate::linalg::qr::qr_thin(&g).q;
    let lora_mass = projection_mass(x, &q);
    let lora_r: f32 = lora_mass.iter().sum::<f32>() / total.max(1e-12);
    // scaled: weight direction masses by σ² (model amplification).
    let scaled: Vec<f32> = mass.iter().zip(s).map(|(m, sv)| m * sv * sv).collect();
    let scaled_total: f32 = scaled.iter().sum();
    ProjectionShares {
        lora_r,
        pissa_r,
        clover_all: 1.0,
        top1_unscaled: mass[0] / total.max(1e-12),
        top1_scaled: scaled[0] / scaled_total.max(1e-12),
    }
}

/// Fig-5: singular values of ΔW = after − before.
pub fn delta_spectrum(before: &Tensor, after: &Tensor) -> Vec<f32> {
    let delta = after.sub(before);
    svd(&delta).s
}

/// Numerical rank of a spectrum at a relative tolerance.
pub fn numerical_rank(s: &[f32], rel_tol: f32) -> usize {
    let top = s.first().copied().unwrap_or(0.0);
    if top <= 0.0 {
        return 0;
    }
    s.iter().filter(|&&x| x > rel_tol * top).count()
}

/// Fig-6: count "intruder" singular vectors among the top-k of `after`:
/// directions whose best cosine similarity against *all* singular vectors
/// of `before` is below `tau` (Shuttleworth et al. use tau ≈ 0.6–0.9).
pub fn intruder_count(before: &Tensor, after: &Tensor, k: usize, tau: f32) -> usize {
    let db = svd(before);
    let da = svd(after);
    let k = k.min(da.u.shape()[1]);
    let mut count = 0;
    // cosine table: U_afterᵀ · U_before  (columns orthonormal ⇒ inner
    // products are cosines).
    let cos = matmul_tn(&da.u, &db.u); // [ka, kb]
    let kb = cos.shape()[1];
    for i in 0..k {
        let mut best = 0.0f32;
        for j in 0..kb {
            best = best.max(cos.at2(i, j).abs());
        }
        if best < tau {
            count += 1;
        }
    }
    count
}

/// Helper for analyses: apply a stacked per-head S update into a flat W
/// (e.g. reconstruct the effective ΔW a CLOVER fine-tune produced on the
/// key projection): `W_eff = U · S · Vᵀ` summed per head into [D, D].
pub fn effective_w(u: &Tensor, s: &Tensor, v: &Tensor, head: usize) -> Tensor {
    // u [H,D,r] (single layer slice), s [H,r,r], v [H,D,r]
    let (d, r) = (u.shape()[1], u.shape()[2]);
    let base_u = head * d * r;
    let base_s = head * r * r;
    let u_b = Tensor::new(vec![d, r], u.data()[base_u..base_u + d * r].to_vec());
    let s_b = Tensor::new(vec![r, r], s.data()[base_s..base_s + r * r].to_vec());
    let v_b = Tensor::new(vec![d, r], v.data()[base_u..base_u + d * r].to_vec());
    crate::linalg::matmul(&crate::linalg::matmul(&u_b, &s_b), &v_b.transpose2())
}

/// KV-cache bytes per token for a decoder layer stack — the paper's
/// motivating metric.  Factorized caches store 2·L·H·r floats vs dense
/// 2·L·H·d.
pub fn kv_bytes_per_token(n_layers: usize, n_heads: usize, rank: usize) -> usize {
    2 * n_layers * n_heads * rank * std::mem::size_of::<f32>()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn projection_mass_identity_basis() {
        // X with known variance along axes; identity basis recovers it.
        let x = Tensor::new(vec![2, 2], vec![3.0, 0.0, 3.0, 0.0]);
        let mass = projection_mass(&x, &Tensor::eye(2));
        assert!((mass[0] - 9.0).abs() < 1e-5);
        assert_eq!(mass[1], 0.0);
    }

    #[test]
    fn pissa_beats_lora_on_lowrank_features() {
        // Features concentrated in a 2-D subspace aligned with U's top dirs.
        let mut rng = Rng::new(3);
        let d = 16;
        let u = crate::linalg::qr::qr_thin(
            &Tensor::new(vec![d, d], rng.normal_vec(d * d, 1.0))
        ).q;
        // X = coeffs on first two basis dirs
        let n = 64;
        let mut xdata = vec![0.0f32; n * d];
        for i in 0..n {
            let c0 = rng.normal() as f32 * 3.0;
            let c1 = rng.normal() as f32;
            for j in 0..d {
                xdata[i * d + j] = c0 * u.at2(j, 0) + c1 * u.at2(j, 1);
            }
        }
        let x = Tensor::new(vec![n, d], xdata);
        let s = vec![1.0f32; d];
        let shares = projection_shares(&x, &u, &s, 2, &mut rng);
        assert!(shares.pissa_r > 0.95, "pissa {}", shares.pissa_r);
        assert!(shares.lora_r < 0.7, "lora {}", shares.lora_r);
        assert_eq!(shares.clover_all, 1.0);
    }

    #[test]
    fn delta_spectrum_rank() {
        let mut rng = Rng::new(1);
        let w = Tensor::new(vec![8, 8], rng.normal_vec(64, 1.0));
        // rank-1 update
        let a = Tensor::new(vec![8, 1], rng.normal_vec(8, 1.0));
        let b = Tensor::new(vec![1, 8], rng.normal_vec(8, 1.0));
        let mut after = w.clone();
        after.add_assign(&crate::linalg::matmul(&a, &b));
        let s = delta_spectrum(&w, &after);
        assert_eq!(numerical_rank(&s, 1e-3), 1);
    }

    #[test]
    fn intruders_detected_for_random_directions() {
        let mut rng = Rng::new(7);
        let w = Tensor::new(vec![12, 12], rng.normal_vec(144, 0.3));
        // identical matrices: no intruders
        assert_eq!(intruder_count(&w, &w, 4, 0.9), 0);
        // add a dominant random rank-1 direction: exactly the intruder setup
        let a = Tensor::new(vec![12, 1], rng.normal_vec(12, 1.0));
        let b = Tensor::new(vec![1, 12], rng.normal_vec(12, 1.0));
        let mut upd = crate::linalg::matmul(&a, &b);
        upd.scale(10.0 / upd.norm());
        let mut after = w.clone();
        after.add_assign(&upd);
        assert!(intruder_count(&w, &after, 2, 0.8) >= 1);
    }

    #[test]
    fn crossover_found() {
        let clover = vec![10.0, 5.0, 0.1, 0.01];
        let vanilla = vec![4.0, 3.0, 2.5, 2.0];
        let c = crossover(&clover, &vanilla).unwrap();
        assert_eq!(c, 2);
    }

    #[test]
    fn kv_bytes_scale_with_rank() {
        let dense = kv_bytes_per_token(4, 8, 32);
        let pruned = kv_bytes_per_token(4, 8, 16);
        assert_eq!(pruned * 2, dense);
    }

    #[test]
    fn matvec_is_used() {
        // keep matvec exercised (analysis helpers rely on it indirectly)
        let a = Tensor::eye(3);
        assert_eq!(crate::linalg::matvec(&a, &[1.0, 2.0, 3.0]), vec![1.0, 2.0, 3.0]);
    }
}
