//! Injectable time source for the serving spine.
//!
//! Everything downstream of the engine measures time as [`Instant`]
//! arithmetic (session arrival/TTFT, batcher wait, deadlines), so the
//! clock produces *real* `Instant` values from both variants:
//!
//! * **wall** — `Instant::now()`, the production default.
//! * **manual** — a fixed epoch captured at construction plus an atomic
//!   nanosecond counter; `now()` is `epoch + nanos` and `sleep()`
//!   *advances the counter instead of blocking*.  The stub backend's
//!   `step_delay`/`width_delay` route through [`Clock::sleep`], so a
//!   manual clock turns simulated step cost into deterministic virtual
//!   time: latency/TTFT assertions become exact and tests run at host
//!   speed.
//!
//! Clones share the same underlying counter, so handing one clock to the
//! engine, the stub spec, and a test gives them a single timeline.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

#[derive(Debug)]
enum Inner {
    Wall { epoch: Instant },
    Manual { epoch: Instant, nanos: AtomicU64 },
}

/// Shared wall/manual time source (see module docs).
#[derive(Clone, Debug)]
pub struct Clock {
    inner: Arc<Inner>,
}

impl Default for Clock {
    fn default() -> Self {
        Self::wall()
    }
}

impl Clock {
    /// Real time: `now()` is `Instant::now()`, `sleep()` blocks.
    pub fn wall() -> Self {
        Self { inner: Arc::new(Inner::Wall { epoch: Instant::now() }) }
    }

    /// Virtual time starting at zero; advanced only by [`Clock::sleep`]
    /// and [`Clock::advance`].
    pub fn manual() -> Self {
        Self {
            inner: Arc::new(Inner::Manual { epoch: Instant::now(), nanos: AtomicU64::new(0) }),
        }
    }

    pub fn is_manual(&self) -> bool {
        matches!(&*self.inner, Inner::Manual { .. })
    }

    /// The current instant on this clock's timeline.
    pub fn now(&self) -> Instant {
        match &*self.inner {
            Inner::Wall { .. } => Instant::now(),
            Inner::Manual { epoch, nanos } => {
                *epoch + Duration::from_nanos(nanos.load(Ordering::Acquire))
            }
        }
    }

    /// Block for `d` (wall) or advance the timeline by `d` (manual).
    pub fn sleep(&self, d: Duration) {
        if d.is_zero() {
            return;
        }
        match &*self.inner {
            Inner::Wall { .. } => std::thread::sleep(d),
            Inner::Manual { .. } => self.advance(d),
        }
    }

    /// Advance a manual clock by `d`.  Panics on a wall clock — virtual
    /// time cannot be pushed forward for the whole host.
    pub fn advance(&self, d: Duration) {
        match &*self.inner {
            Inner::Wall { .. } => panic!("Clock::advance on a wall clock"),
            Inner::Manual { nanos, .. } => {
                nanos.fetch_add(d.as_nanos() as u64, Ordering::AcqRel);
            }
        }
    }

    /// Seconds from this clock's epoch to `t` (saturating at zero for
    /// instants before the epoch).  Trace timestamps use this so every
    /// event in one recording shares an origin.
    pub fn secs_since_epoch(&self, t: Instant) -> f64 {
        let epoch = match &*self.inner {
            Inner::Wall { epoch } | Inner::Manual { epoch, .. } => *epoch,
        };
        t.saturating_duration_since(epoch).as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn wall_clock_moves_forward_and_sleep_blocks() {
        let c = Clock::wall();
        assert!(!c.is_manual());
        let a = c.now();
        c.sleep(Duration::from_millis(2));
        let b = c.now();
        assert!(b > a);
        assert!(c.secs_since_epoch(b) >= c.secs_since_epoch(a));
    }

    #[test]
    fn manual_clock_is_exact_and_sleep_is_free() {
        let c = Clock::manual();
        assert!(c.is_manual());
        let t0 = c.now();
        let real = Instant::now();
        c.sleep(Duration::from_secs(3600));
        assert!(real.elapsed() < Duration::from_secs(5), "manual sleep must not block");
        let t1 = c.now();
        assert_eq!(t1.duration_since(t0), Duration::from_secs(3600));
        assert_eq!(c.secs_since_epoch(t1), 3600.0);
    }

    #[test]
    fn clones_share_one_timeline() {
        let a = Clock::manual();
        let b = a.clone();
        b.advance(Duration::from_millis(250));
        assert_eq!(a.secs_since_epoch(a.now()), 0.25);
    }

    #[test]
    #[should_panic(expected = "wall clock")]
    fn advance_on_wall_clock_panics() {
        Clock::wall().advance(Duration::from_millis(1));
    }
}
