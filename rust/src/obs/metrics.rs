//! Hand-rolled counter/gauge/histogram registry with Prometheus-style
//! text exposition and a JSON dump (via [`crate::config::json`] — no
//! external metrics crates).
//!
//! Metric names follow the Prometheus data model: a bare family name
//! (`clover_completed_total`) or a family plus labels
//! (`clover_in_flight{gateway="r8"}`).  The registry treats the full
//! string as the series key; exposition groups series by family for the
//! `# TYPE` headers.  Interior mutability (one mutex) makes a shared
//! `Arc<Registry>` usable from the gateway worker thread and the
//! submitting side at once.

use std::collections::BTreeMap;

use crate::config::json::Json;
// Through the shim so the loom lane can model registry contention with
// the same lock type the gateway publishes through.
use crate::util::sync::Mutex;

/// Cumulative histogram: `counts[i]` tokens observations `<= bounds[i]`,
/// with an implicit `+Inf` bucket (`count`).
#[derive(Clone, Debug)]
pub struct Hist {
    pub bounds: Vec<f64>,
    pub counts: Vec<u64>,
    pub sum: f64,
    pub count: u64,
}

impl Hist {
    fn new(bounds: &[f64]) -> Self {
        Self { bounds: bounds.to_vec(), counts: vec![0; bounds.len()], sum: 0.0, count: 0 }
    }

    fn observe(&mut self, v: f64) {
        for (i, b) in self.bounds.iter().enumerate() {
            if v <= *b {
                self.counts[i] += 1;
            }
        }
        self.sum += v;
        self.count += 1;
    }
}

#[derive(Default)]
struct Series {
    counters: BTreeMap<String, f64>,
    gauges: BTreeMap<String, f64>,
    hists: BTreeMap<String, Hist>,
}

/// Shared metrics registry (see module docs).
#[derive(Default)]
pub struct Registry {
    series: Mutex<Series>,
}

/// `name{labels}` → `(name, "{labels}")`; the suffix is empty for bare
/// families.
fn split_family(series: &str) -> (&str, &str) {
    match series.find('{') {
        Some(i) => (&series[..i], &series[i..]),
        None => (series, ""),
    }
}

impl Registry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `v` to a (monotonic) counter series, creating it at zero.
    pub fn counter_add(&self, series: &str, v: f64) {
        let mut s = self.series.lock().unwrap_or_else(|e| e.into_inner());
        *s.counters.entry(series.to_string()).or_insert(0.0) += v;
    }

    /// Set a gauge series to `v`.
    pub fn gauge_set(&self, series: &str, v: f64) {
        let mut s = self.series.lock().unwrap_or_else(|e| e.into_inner());
        s.gauges.insert(series.to_string(), v);
    }

    /// Add `v` (may be negative) to a gauge series, creating it at zero.
    pub fn gauge_add(&self, series: &str, v: f64) {
        let mut s = self.series.lock().unwrap_or_else(|e| e.into_inner());
        *s.gauges.entry(series.to_string()).or_insert(0.0) += v;
    }

    /// Record one observation into a histogram series; `bounds` fixes the
    /// bucket layout on first use (later calls may pass the same bounds
    /// or `&[]` to reuse the existing layout).
    pub fn observe(&self, series: &str, bounds: &[f64], v: f64) {
        let mut s = self.series.lock().unwrap_or_else(|e| e.into_inner());
        s.hists.entry(series.to_string()).or_insert_with(|| Hist::new(bounds)).observe(v);
    }

    /// Current value of a counter or gauge series (tests, stats lines).
    pub fn get(&self, series: &str) -> Option<f64> {
        let s = self.series.lock().unwrap_or_else(|e| e.into_inner());
        s.counters.get(series).or_else(|| s.gauges.get(series)).copied()
    }

    /// Snapshot of a histogram series.
    pub fn hist(&self, series: &str) -> Option<Hist> {
        self.series.lock().unwrap_or_else(|e| e.into_inner()).hists.get(series).cloned()
    }

    /// Prometheus text exposition (format 0.0.4): `# TYPE` per family,
    /// one line per series, histogram `_bucket`/`_sum`/`_count` expansion.
    pub fn prometheus_text(&self) -> String {
        let s = self.series.lock().unwrap_or_else(|e| e.into_inner());
        let mut out = String::new();
        let mut last_family = String::new();
        let mut typed = |out: &mut String, family: &str, kind: &str| {
            if family != last_family {
                out.push_str(&format!("# TYPE {family} {kind}\n"));
                last_family = family.to_string();
            }
        };
        for (series, v) in &s.counters {
            let (family, _) = split_family(series);
            typed(&mut out, family, "counter");
            out.push_str(&format!("{series} {v}\n"));
        }
        for (series, v) in &s.gauges {
            let (family, _) = split_family(series);
            typed(&mut out, family, "gauge");
            out.push_str(&format!("{series} {v}\n"));
        }
        for (series, h) in &s.hists {
            let (family, labels) = split_family(series);
            typed(&mut out, family, "histogram");
            let inner = labels.trim_start_matches('{').trim_end_matches('}');
            let with = |extra: &str| {
                if inner.is_empty() {
                    format!("{{{extra}}}")
                } else {
                    format!("{{{inner},{extra}}}")
                }
            };
            for (b, c) in h.bounds.iter().zip(&h.counts) {
                out.push_str(&format!("{family}_bucket{} {c}\n", with(&format!("le=\"{b}\""))));
            }
            out.push_str(&format!("{family}_bucket{} {}\n", with("le=\"+Inf\""), h.count));
            out.push_str(&format!("{family}_sum{labels} {}\n", h.sum));
            out.push_str(&format!("{family}_count{labels} {}\n", h.count));
        }
        out
    }

    /// JSON dump: `{"counters": {...}, "gauges": {...}, "histograms":
    /// {series: {"bounds": [...], "counts": [...], "sum": s, "count": n}}}`.
    pub fn to_json(&self) -> Json {
        let s = self.series.lock().unwrap_or_else(|e| e.into_inner());
        let num_map =
            |m: &BTreeMap<String, f64>| m.iter().map(|(k, v)| (k.clone(), Json::Num(*v)));
        let mut hists = BTreeMap::new();
        for (series, h) in &s.hists {
            let mut o = BTreeMap::new();
            o.insert(
                "bounds".to_string(),
                Json::Arr(h.bounds.iter().map(|b| Json::Num(*b)).collect()),
            );
            o.insert(
                "counts".to_string(),
                Json::Arr(h.counts.iter().map(|c| Json::Num(*c as f64)).collect()),
            );
            o.insert("sum".to_string(), Json::Num(h.sum));
            o.insert("count".to_string(), Json::Num(h.count as f64));
            hists.insert(series.clone(), Json::Obj(o));
        }
        let mut root = BTreeMap::new();
        root.insert("counters".to_string(), Json::Obj(num_map(&s.counters).collect()));
        root.insert("gauges".to_string(), Json::Obj(num_map(&s.gauges).collect()));
        root.insert("histograms".to_string(), Json::Obj(hists));
        Json::Obj(root)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::json::to_string;

    #[test]
    fn counters_and_gauges_accumulate_per_series() {
        let r = Registry::new();
        r.counter_add("done_total", 1.0);
        r.counter_add("done_total", 2.0);
        r.gauge_set("in_flight{gateway=\"a\"}", 3.0);
        r.gauge_set("in_flight{gateway=\"b\"}", 5.0);
        r.gauge_add("in_flight{gateway=\"b\"}", -2.0);
        assert_eq!(r.get("done_total"), Some(3.0));
        assert_eq!(r.get("in_flight{gateway=\"a\"}"), Some(3.0));
        assert_eq!(r.get("in_flight{gateway=\"b\"}"), Some(3.0));
        assert_eq!(r.get("missing"), None);
    }

    #[test]
    fn histogram_buckets_are_cumulative() {
        let r = Registry::new();
        for v in [0.5, 1.5, 2.5, 10.0] {
            r.observe("lat_s", &[1.0, 2.0, 4.0], v);
        }
        let h = r.hist("lat_s").unwrap();
        assert_eq!(h.counts, vec![1, 2, 3]);
        assert_eq!(h.count, 4);
        assert_eq!(h.sum, 14.5);
    }

    #[test]
    fn prometheus_text_has_type_headers_and_histogram_expansion() {
        let r = Registry::new();
        r.counter_add("clover_done_total", 2.0);
        r.gauge_set("clover_in_flight{gateway=\"r8\"}", 1.0);
        r.observe("clover_ttft_s", &[0.1], 0.05);
        let text = r.prometheus_text();
        assert!(text.contains("# TYPE clover_done_total counter\n"));
        assert!(text.contains("clover_done_total 2\n"));
        assert!(text.contains("# TYPE clover_in_flight gauge\n"));
        assert!(text.contains("clover_in_flight{gateway=\"r8\"} 1\n"));
        assert!(text.contains("# TYPE clover_ttft_s histogram\n"));
        assert!(text.contains("clover_ttft_s_bucket{le=\"0.1\"} 1\n"));
        assert!(text.contains("clover_ttft_s_bucket{le=\"+Inf\"} 1\n"));
        assert!(text.contains("clover_ttft_s_sum 0.05\n"));
        assert!(text.contains("clover_ttft_s_count 1\n"));
    }

    #[test]
    fn type_header_emitted_once_per_family() {
        let r = Registry::new();
        r.gauge_set("g{x=\"1\"}", 1.0);
        r.gauge_set("g{x=\"2\"}", 2.0);
        let text = r.prometheus_text();
        assert_eq!(text.matches("# TYPE g gauge").count(), 1);
    }

    #[test]
    fn json_dump_round_trips() {
        let r = Registry::new();
        r.counter_add("c", 1.0);
        r.gauge_set("g", 2.5);
        r.observe("h", &[1.0], 0.5);
        let parsed = Json::parse(&to_string(&r.to_json())).unwrap();
        let Json::Obj(root) = parsed else { panic!("object root") };
        let Json::Obj(counters) = &root["counters"] else { panic!() };
        assert_eq!(counters["c"], Json::Num(1.0));
        let Json::Obj(hists) = &root["histograms"] else { panic!() };
        let Json::Obj(h) = &hists["h"] else { panic!() };
        assert_eq!(h["count"], Json::Num(1.0));
    }
}
