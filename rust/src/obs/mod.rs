//! Observability for the serving spine: injectable clocks, a metrics
//! registry, and the flight-recorder/trace layer.
//!
//! The serve engine stays unaware of any of this beyond the optional
//! [`StepHook`](crate::serve::StepHook) tap methods (`on_step`/`on_span`,
//! gated behind `wants_step_events` so a hookless serve pays nothing).
//! The pieces:
//!
//! * [`clock::Clock`] — wall or manual (virtual) time, producing real
//!   `Instant`s so sessions, batchers, and deadlines need no changes.
//!   The stub backend's simulated step delays advance a manual clock
//!   instead of blocking, making latency tests exact and fast.
//! * [`metrics::Registry`] — hand-rolled counters/gauges/histograms with
//!   Prometheus text exposition and a JSON dump; shared `Arc` between
//!   gateway workers (producers) and the router/CLI (consumers).
//! * [`trace::TraceSink`] — per-step flight-recorder ring + per-request
//!   span timelines, exportable as Chrome trace-event JSON and strong
//!   enough to reconstruct `ServeMetrics` aggregates.

pub mod clock;
pub mod metrics;
pub mod trace;

pub use clock::Clock;
pub use metrics::Registry;
pub use trace::{
    ReconMetrics, RequestSpan, SpanEvent, SpanPoint, StepEvent, TeeHook, TraceSink,
};
