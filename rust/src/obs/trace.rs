//! Flight recorder and span timelines for the serve engine.
//!
//! [`TraceSink`] is a [`StepHook`] that assembles two views of a serve:
//!
//! * **step events** — one [`StepEvent`] per fused (or draft) step with
//!   the slab width, lane census, prefill/decode/draft/verify token mix,
//!   step wall time, and KV live/freed bytes, kept in a bounded
//!   flight-recorder ring (oldest evicted first);
//! * **request spans** — a [`RequestSpan`] per request id tracking the
//!   queued → admitted → prefill chunks → first token → spec rounds →
//!   done/cancelled timeline with monotonic engine-clock timestamps.
//!
//! Both export as Chrome trace-event JSON (`{"traceEvents": [...]}` of
//! `"X"` complete events — loadable in Perfetto/`chrome://tracing`), and
//! the span view is strong enough to *reconstruct* the engine's
//! [`ServeMetrics`](crate::serve::ServeMetrics) aggregates — the bench
//! checker uses that to prove the taps observe faithfully.
//!
//! A cancel-storm detector arms a dump request when too many
//! cancellations land inside a sliding window; the gateway/CLI drain it
//! (plus an explicit `shutdown` trigger) into flight-recorder dumps.

use std::collections::{BTreeMap, VecDeque};

use crate::config::json::Json;
use crate::serve::engine::percentile;
use crate::serve::{Cancellation, CancelReason, Completion, FailReason, Request, StepHook};

/// One engine step as observed by the tap (see module docs).
#[derive(Clone, Debug)]
pub struct StepEvent {
    /// Global step sequence number (draft micro-steps included).
    pub seq: usize,
    /// Engine decode-step counter after this step (unchanged by drafts).
    pub decode_step: usize,
    /// Slab width the step ran at.
    pub width: usize,
    /// Draft-model micro-step (width-1 proposal) rather than a fused step.
    pub draft: bool,
    /// Start of the step, seconds on the engine clock.
    pub t_s: f64,
    /// Step wall time in seconds.
    pub dur_s: f64,
    /// Lanes occupied by live sessions / total lanes.
    pub lanes_live: usize,
    pub lanes_total: usize,
    /// Row-token mix of the step's slabs (pads excluded).
    pub prefill_tokens: usize,
    pub decode_tokens: usize,
    pub draft_tokens: usize,
    pub verify_tokens: usize,
    /// Transient-fault retries this step burned before succeeding (0 on
    /// the untroubled path).
    pub retries: usize,
    /// KV accounting after the step.
    pub kv_live_bytes: usize,
    pub kv_freed_bytes: usize,
    /// Bytes the prefix cache holds (gauge; 0 when caching is off).
    pub kv_cached_bytes: usize,
    /// Cumulative bytes released by prefix-cache eviction under memory
    /// pressure.
    pub prefix_evicted_bytes: usize,
}

/// A point on a request's span timeline.
#[derive(Clone, Debug, PartialEq)]
pub enum SpanPoint {
    /// Request arrival (batcher-queue entry); `t_s` is the arrival stamp.
    Queued,
    /// Admitted into KV lane `lane`.
    Admitted { lane: usize },
    /// A prefill chunk of `tokens` prompt tokens was consumed.
    PrefillChunk { tokens: usize },
    /// `tokens` leading prompt tokens were attached from the prefix
    /// cache at admission — they never occupy a prefill step.
    PrefixHit { tokens: usize },
    /// The request was migrated off a saturated engine's queue; its span
    /// continues on the target engine (fresh Queued/Admitted stamps).
    Migrated,
    /// First generated token sampled.
    FirstToken,
    /// A speculative round verified: `drafted` proposed, `accepted` kept.
    SpecRound { drafted: usize, accepted: usize },
    /// Finished normally with `generated` non-prompt tokens.
    Done { generated: usize },
    /// Cancelled (user or deadline) with `generated` tokens so far.
    Cancelled { generated: usize },
    /// Failed terminally (backend death or a poisoned lane) with
    /// `generated` tokens so far.
    Failed { generated: usize },
}

/// Timestamped [`SpanPoint`] for one request.
#[derive(Clone, Debug)]
pub struct SpanEvent {
    pub id: u64,
    pub t_s: f64,
    pub point: SpanPoint,
}

/// Assembled per-request timeline.
#[derive(Clone, Debug, Default)]
pub struct RequestSpan {
    pub id: u64,
    pub queued_s: Option<f64>,
    pub admitted_s: Option<f64>,
    pub lane: Option<usize>,
    pub first_token_s: Option<f64>,
    /// `(t_s, tokens)` per prefill chunk.
    pub prefill_chunks: Vec<(f64, usize)>,
    /// Prompt tokens attached from the prefix cache (None = cold).
    pub prefix_hit_tokens: Option<usize>,
    /// The request crossed engines via queue migration.
    pub migrated: bool,
    /// `(t_s, drafted, accepted)` per speculative round.
    pub spec_rounds: Vec<(f64, usize, usize)>,
    /// Terminal stamp; `None` while the request is in flight.
    pub end_s: Option<f64>,
    pub generated: usize,
    pub cancelled: bool,
    /// The request ended in a `Failed` terminal (fault path).
    pub failed: bool,
}

impl RequestSpan {
    pub fn closed(&self) -> bool {
        self.end_s.is_some()
    }
}

/// Aggregates recomputed purely from span timelines; the bench checker
/// compares them against the engine's own `ServeMetrics`.
#[derive(Clone, Debug, Default)]
pub struct ReconMetrics {
    pub completed: usize,
    pub cancelled: usize,
    pub failed: usize,
    pub generated_tokens: usize,
    pub ttft_p50_s: f64,
    pub ttft_p99_s: f64,
}

/// Cancel-storm detector: `threshold` cancels within `window_s` seconds
/// arms a flight-recorder dump.
const STORM_WINDOW_S: f64 = 1.0;
const STORM_THRESHOLD: usize = 8;

/// Fault-storm detector: `Failed` terminals are rarer and graver than
/// cancels, so the threshold is lower (same sliding window).
const FAULT_STORM_THRESHOLD: usize = 4;

/// Flight recorder + span assembler (see module docs).
#[derive(Debug)]
pub struct TraceSink {
    ring_cap: usize,
    ring: VecDeque<StepEvent>,
    /// Total step events observed (ring evictions included).
    steps_seen: usize,
    spans: BTreeMap<u64, RequestSpan>,
    cancel_times: VecDeque<f64>,
    fault_times: VecDeque<f64>,
    dump_reason: Option<String>,
}

impl Default for TraceSink {
    fn default() -> Self {
        Self::new(4096)
    }
}

impl TraceSink {
    /// Recorder keeping at most `ring_cap` recent step events.
    pub fn new(ring_cap: usize) -> Self {
        Self {
            ring_cap: ring_cap.max(1),
            ring: VecDeque::new(),
            steps_seen: 0,
            spans: BTreeMap::new(),
            cancel_times: VecDeque::new(),
            fault_times: VecDeque::new(),
            dump_reason: None,
        }
    }

    pub fn record_step(&mut self, ev: &StepEvent) {
        if self.ring.len() == self.ring_cap {
            self.ring.pop_front();
        }
        self.ring.push_back(ev.clone());
        self.steps_seen += 1;
    }

    pub fn record_span(&mut self, ev: &SpanEvent) {
        let span = self.spans.entry(ev.id).or_insert_with(|| RequestSpan {
            id: ev.id,
            ..RequestSpan::default()
        });
        match ev.point {
            SpanPoint::Queued => span.queued_s = Some(ev.t_s),
            SpanPoint::Admitted { lane } => {
                span.admitted_s = Some(ev.t_s);
                span.lane = Some(lane);
            }
            SpanPoint::PrefillChunk { tokens } => span.prefill_chunks.push((ev.t_s, tokens)),
            SpanPoint::PrefixHit { tokens } => span.prefix_hit_tokens = Some(tokens),
            SpanPoint::Migrated => span.migrated = true,
            SpanPoint::FirstToken => {
                if span.first_token_s.is_none() {
                    span.first_token_s = Some(ev.t_s);
                }
            }
            SpanPoint::SpecRound { drafted, accepted } => {
                span.spec_rounds.push((ev.t_s, drafted, accepted));
            }
            SpanPoint::Done { generated } => {
                span.end_s = Some(ev.t_s);
                span.generated = generated;
            }
            SpanPoint::Cancelled { generated } => {
                span.end_s = Some(ev.t_s);
                span.generated = generated;
                span.cancelled = true;
                self.cancel_times.push_back(ev.t_s);
                while let Some(&t0) = self.cancel_times.front() {
                    if ev.t_s - t0 > STORM_WINDOW_S {
                        self.cancel_times.pop_front();
                    } else {
                        break;
                    }
                }
                if self.cancel_times.len() >= STORM_THRESHOLD && self.dump_reason.is_none() {
                    self.dump_reason = Some(format!(
                        "cancel-storm: {} cancels within {STORM_WINDOW_S}s",
                        self.cancel_times.len()
                    ));
                }
            }
            SpanPoint::Failed { generated } => {
                span.end_s = Some(ev.t_s);
                span.generated = generated;
                span.failed = true;
                self.fault_times.push_back(ev.t_s);
                while let Some(&t0) = self.fault_times.front() {
                    if ev.t_s - t0 > STORM_WINDOW_S {
                        self.fault_times.pop_front();
                    } else {
                        break;
                    }
                }
                if self.fault_times.len() >= FAULT_STORM_THRESHOLD && self.dump_reason.is_none() {
                    self.dump_reason = Some(format!(
                        "fault-storm: {} request failures within {STORM_WINDOW_S}s",
                        self.fault_times.len()
                    ));
                }
            }
        }
    }

    /// Arm a flight-recorder dump explicitly (overload, shutdown).
    pub fn request_dump(&mut self, reason: &str) {
        if self.dump_reason.is_none() {
            self.dump_reason = Some(reason.to_string());
        }
    }

    /// Consume the armed dump trigger, if any: `(reason, flight dump)`.
    pub fn take_dump(&mut self) -> Option<(String, Json)> {
        let reason = self.dump_reason.take()?;
        let dump = self.flight_dump(&reason);
        Some((reason, dump))
    }

    pub fn steps(&self) -> impl Iterator<Item = &StepEvent> {
        self.ring.iter()
    }

    pub fn steps_seen(&self) -> usize {
        self.steps_seen
    }

    pub fn ring_len(&self) -> usize {
        self.ring.len()
    }

    pub fn spans(&self) -> impl Iterator<Item = &RequestSpan> {
        self.spans.values()
    }

    pub fn span(&self, id: u64) -> Option<&RequestSpan> {
        self.spans.get(&id)
    }

    /// Spans with no terminal point — must be 0 after a drained serve, or
    /// the taps leaked a request.
    pub fn open_spans(&self) -> usize {
        self.spans.values().filter(|s| !s.closed()).count()
    }

    /// Recompute serve aggregates from span timelines alone.  TTFT per
    /// request is `first_token - queued` (or `end - queued` when nothing
    /// was generated, matching `Completion::ttft_s`); percentiles use the
    /// engine's own nearest-rank [`percentile`].
    pub fn reconstruct(&self) -> ReconMetrics {
        let mut m = ReconMetrics::default();
        let mut ttfts = Vec::new();
        for s in self.spans.values() {
            let Some(end) = s.end_s else { continue };
            if s.cancelled {
                m.cancelled += 1;
                continue;
            }
            if s.failed {
                m.failed += 1;
                continue;
            }
            m.completed += 1;
            m.generated_tokens += s.generated;
            let queued = s.queued_s.unwrap_or(end);
            ttfts.push(s.first_token_s.unwrap_or(end) - queued);
        }
        ttfts.sort_by(f64::total_cmp);
        m.ttft_p50_s = percentile(&ttfts, 0.50);
        m.ttft_p99_s = percentile(&ttfts, 0.99);
        m
    }

    // ---- Chrome trace-event export -----------------------------------

    /// Full recording as Chrome trace-event JSON: one `"X"` complete
    /// event per *closed* request span (pid 1, tid = request id), one per
    /// ring step event (pid 0, tid 0), plus instant (`"i"`) marks for
    /// first tokens.  `ts`/`dur` are microseconds per the trace-event
    /// spec.
    pub fn chrome_trace(&self) -> Json {
        let mut events: Vec<Json> = Vec::new();
        for ev in &self.ring {
            let mut args = BTreeMap::new();
            args.insert("width".into(), Json::Num(ev.width as f64));
            args.insert("decode_step".into(), Json::Num(ev.decode_step as f64));
            args.insert("lanes_live".into(), Json::Num(ev.lanes_live as f64));
            args.insert("lanes_total".into(), Json::Num(ev.lanes_total as f64));
            args.insert("prefill_tokens".into(), Json::Num(ev.prefill_tokens as f64));
            args.insert("decode_tokens".into(), Json::Num(ev.decode_tokens as f64));
            args.insert("draft_tokens".into(), Json::Num(ev.draft_tokens as f64));
            args.insert("verify_tokens".into(), Json::Num(ev.verify_tokens as f64));
            args.insert("retries".into(), Json::Num(ev.retries as f64));
            args.insert("kv_live_bytes".into(), Json::Num(ev.kv_live_bytes as f64));
            args.insert("kv_freed_bytes".into(), Json::Num(ev.kv_freed_bytes as f64));
            args.insert("kv_cached_bytes".into(), Json::Num(ev.kv_cached_bytes as f64));
            args.insert(
                "prefix_evicted_bytes".into(),
                Json::Num(ev.prefix_evicted_bytes as f64),
            );
            let name = if ev.draft {
                format!("draft step {}", ev.seq)
            } else {
                format!("step {} w={}", ev.seq, ev.width)
            };
            events.push(complete_event(&name, "step", 0, 0, ev.t_s, ev.dur_s, args));
        }
        for s in self.spans.values() {
            let Some(end) = s.end_s else { continue };
            let start = s.queued_s.or(s.admitted_s).unwrap_or(end);
            let mut args = BTreeMap::new();
            args.insert("generated".into(), Json::Num(s.generated as f64));
            args.insert("cancelled".into(), Json::Bool(s.cancelled));
            if s.failed {
                args.insert("failed".into(), Json::Bool(true));
            }
            args.insert("prefill_chunks".into(), Json::Num(s.prefill_chunks.len() as f64));
            args.insert("spec_rounds".into(), Json::Num(s.spec_rounds.len() as f64));
            if let Some(hit) = s.prefix_hit_tokens {
                args.insert("prefix_hit_tokens".into(), Json::Num(hit as f64));
            }
            if s.migrated {
                args.insert("migrated".into(), Json::Bool(true));
            }
            if let Some(lane) = s.lane {
                args.insert("lane".into(), Json::Num(lane as f64));
            }
            if let (Some(q), Some(a)) = (s.queued_s, s.admitted_s) {
                args.insert("queue_wait_s".into(), Json::Num(a - q));
            }
            if let (Some(q), Some(f)) = (s.queued_s, s.first_token_s) {
                args.insert("ttft_s".into(), Json::Num(f - q));
            }
            events.push(complete_event(
                &format!("req {}", s.id),
                "request",
                1,
                s.id as usize,
                start,
                end - start,
                args,
            ));
            if let Some(f) = s.first_token_s {
                let mut ev = BTreeMap::new();
                ev.insert("name".into(), Json::Str("first token".into()));
                ev.insert("cat".into(), Json::Str("request".into()));
                ev.insert("ph".into(), Json::Str("i".into()));
                ev.insert("s".into(), Json::Str("t".into()));
                ev.insert("pid".into(), Json::Num(1.0));
                ev.insert("tid".into(), Json::Num(s.id as f64));
                ev.insert("ts".into(), Json::Num(f * 1e6));
                events.push(Json::Obj(ev));
            }
        }
        trace_root(events, self.spans.len(), self.steps_seen)
    }

    /// Ring-only dump for the armed trigger: recent steps plus any spans
    /// still open at dump time (the requests an incident interrupted).
    pub fn flight_dump(&self, reason: &str) -> Json {
        let mut events: Vec<Json> = Vec::new();
        for ev in &self.ring {
            let mut args = BTreeMap::new();
            args.insert("width".into(), Json::Num(ev.width as f64));
            args.insert("lanes_live".into(), Json::Num(ev.lanes_live as f64));
            args.insert("kv_live_bytes".into(), Json::Num(ev.kv_live_bytes as f64));
            let name = if ev.draft {
                format!("draft step {}", ev.seq)
            } else {
                format!("step {} w={}", ev.seq, ev.width)
            };
            events.push(complete_event(&name, "step", 0, 0, ev.t_s, ev.dur_s, args));
        }
        let mut root = trace_root(events, self.spans.len(), self.steps_seen);
        if let Json::Obj(o) = &mut root {
            if let Some(Json::Obj(other)) = o.get_mut("otherData") {
                other.insert("dump_reason".into(), Json::Str(reason.into()));
                other.insert("open_spans".into(), Json::Num(self.open_spans() as f64));
            }
        }
        root
    }
}

fn complete_event(
    name: &str,
    cat: &str,
    pid: usize,
    tid: usize,
    t_s: f64,
    dur_s: f64,
    args: BTreeMap<String, Json>,
) -> Json {
    let mut ev = BTreeMap::new();
    ev.insert("name".into(), Json::Str(name.into()));
    ev.insert("cat".into(), Json::Str(cat.into()));
    ev.insert("ph".into(), Json::Str("X".into()));
    ev.insert("pid".into(), Json::Num(pid as f64));
    ev.insert("tid".into(), Json::Num(tid as f64));
    ev.insert("ts".into(), Json::Num(t_s * 1e6));
    ev.insert("dur".into(), Json::Num(dur_s * 1e6));
    ev.insert("args".into(), Json::Obj(args));
    Json::Obj(ev)
}

fn trace_root(events: Vec<Json>, requests: usize, steps_seen: usize) -> Json {
    let mut other = BTreeMap::new();
    other.insert("requests".into(), Json::Num(requests as f64));
    other.insert("steps_seen".into(), Json::Num(steps_seen as f64));
    let mut root = BTreeMap::new();
    root.insert("traceEvents".into(), Json::Arr(events));
    root.insert("displayTimeUnit".into(), Json::Str("ms".into()));
    root.insert("otherData".into(), Json::Obj(other));
    Json::Obj(root)
}

impl StepHook for TraceSink {
    fn wants_step_events(&self) -> bool {
        true
    }

    fn on_step(&mut self, ev: &StepEvent) {
        self.record_step(ev);
    }

    fn on_span(&mut self, ev: &SpanEvent) {
        self.record_span(ev);
    }
}

/// Forward every hook callback to two hooks.  Control-flow callbacks
/// (ingress, cancellations) delegate to the *primary* only — the
/// secondary is a pure observer (a [`TraceSink`], a stats printer).
pub struct TeeHook<'a> {
    pub primary: &'a mut dyn StepHook,
    pub observer: &'a mut dyn StepHook,
}

impl StepHook for TeeHook<'_> {
    fn poll_ingress(&mut self, idle: bool) -> Option<Vec<Request>> {
        self.primary.poll_ingress(idle)
    }

    fn take_cancellations(&mut self, now: std::time::Instant) -> Vec<Cancellation> {
        self.primary.take_cancellations(now)
    }

    fn wants_step_events(&self) -> bool {
        self.primary.wants_step_events() || self.observer.wants_step_events()
    }

    fn on_started(&mut self, id: u64, lane: usize, step: usize) {
        self.primary.on_started(id, lane, step);
        self.observer.on_started(id, lane, step);
    }

    fn on_token(&mut self, id: u64, pos: usize, token: i32, step: usize) {
        self.primary.on_token(id, pos, token, step);
        self.observer.on_token(id, pos, token, step);
    }

    fn on_done(&mut self, completion: &Completion) {
        self.primary.on_done(completion);
        self.observer.on_done(completion);
    }

    fn on_cancelled(&mut self, id: u64, tokens: Vec<i32>, reason: CancelReason, step: usize) {
        self.primary.on_cancelled(id, tokens.clone(), reason, step);
        self.observer.on_cancelled(id, tokens, reason, step);
    }

    fn on_failed(&mut self, id: u64, tokens: Vec<i32>, reason: FailReason, step: usize) {
        self.primary.on_failed(id, tokens.clone(), reason, step);
        self.observer.on_failed(id, tokens, reason, step);
    }

    fn on_step(&mut self, ev: &StepEvent) {
        self.primary.on_step(ev);
        self.observer.on_step(ev);
    }

    fn on_span(&mut self, ev: &SpanEvent) {
        self.primary.on_span(ev);
        self.observer.on_span(ev);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step(seq: usize, t_s: f64) -> StepEvent {
        StepEvent {
            seq,
            decode_step: seq,
            width: 8,
            draft: false,
            t_s,
            dur_s: 0.001,
            lanes_live: 2,
            lanes_total: 8,
            prefill_tokens: 8,
            decode_tokens: 1,
            draft_tokens: 0,
            verify_tokens: 0,
            retries: 0,
            kv_live_bytes: 1024,
            kv_freed_bytes: 0,
            kv_cached_bytes: 0,
            prefix_evicted_bytes: 0,
        }
    }

    fn span(id: u64, t_s: f64, point: SpanPoint) -> SpanEvent {
        SpanEvent { id, t_s, point }
    }

    #[test]
    fn ring_is_bounded_and_counts_evictions() {
        let mut sink = TraceSink::new(4);
        for i in 0..10 {
            sink.record_step(&step(i, i as f64));
        }
        assert_eq!(sink.ring_len(), 4);
        assert_eq!(sink.steps_seen(), 10);
        let seqs: Vec<usize> = sink.steps().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9]);
    }

    #[test]
    fn spans_assemble_and_reconstruct_aggregates() {
        let mut sink = TraceSink::default();
        for (id, ttft) in [(1u64, 0.5), (2, 1.5)] {
            sink.record_span(&span(id, 0.0, SpanPoint::Queued));
            sink.record_span(&span(id, 0.1, SpanPoint::Admitted { lane: id as usize }));
            sink.record_span(&span(id, 0.2, SpanPoint::PrefillChunk { tokens: 8 }));
            sink.record_span(&span(id, ttft, SpanPoint::FirstToken));
            sink.record_span(&span(id, ttft + 1.0, SpanPoint::Done { generated: 4 }));
        }
        sink.record_span(&span(1, 0.05, SpanPoint::PrefixHit { tokens: 32 }));
        sink.record_span(&span(2, 0.05, SpanPoint::Migrated));
        sink.record_span(&span(3, 0.0, SpanPoint::Queued));
        sink.record_span(&span(3, 0.3, SpanPoint::Cancelled { generated: 0 }));
        assert_eq!(sink.open_spans(), 0);
        assert_eq!(sink.span(1).unwrap().prefix_hit_tokens, Some(32));
        assert!(sink.span(2).unwrap().migrated, "migration marks the span");
        assert!(!sink.span(1).unwrap().migrated);
        let m = sink.reconstruct();
        assert_eq!(m.completed, 2);
        assert_eq!(m.cancelled, 1);
        assert_eq!(m.generated_tokens, 8);
        assert_eq!(m.ttft_p50_s, 1.0);
        assert_eq!(m.ttft_p99_s, 1.5);
    }

    #[test]
    fn cancel_storm_arms_a_dump_quiet_cancels_do_not() {
        let mut quiet = TraceSink::default();
        for i in 0..STORM_THRESHOLD {
            let t = i as f64 * 10.0;
            quiet.record_span(&span(i as u64, t, SpanPoint::Cancelled { generated: 0 }));
        }
        assert!(quiet.take_dump().is_none(), "spread-out cancels are not a storm");

        let mut storm = TraceSink::default();
        storm.record_step(&step(0, 0.0));
        for i in 0..STORM_THRESHOLD {
            let t = i as f64 * 0.01;
            storm.record_span(&span(i as u64, t, SpanPoint::Cancelled { generated: 0 }));
        }
        let (reason, dump) = storm.take_dump().expect("storm arms a dump");
        assert!(reason.contains("cancel-storm"));
        let Json::Obj(root) = dump else { panic!("object dump") };
        let Json::Obj(other) = &root["otherData"] else { panic!() };
        assert_eq!(other["dump_reason"], Json::Str(reason));
        assert!(storm.take_dump().is_none(), "trigger is consumed");
    }

    #[test]
    fn failed_spans_close_count_and_storm_arms_a_dump() {
        let mut sink = TraceSink::default();
        sink.record_span(&span(1, 0.0, SpanPoint::Queued));
        sink.record_span(&span(1, 0.2, SpanPoint::Failed { generated: 3 }));
        assert_eq!(sink.open_spans(), 0, "Failed is terminal");
        let s = sink.span(1).unwrap();
        assert!(s.failed && !s.cancelled);
        assert_eq!(s.generated, 3);
        let m = sink.reconstruct();
        assert_eq!((m.completed, m.cancelled, m.failed), (0, 0, 1));
        assert!(sink.take_dump().is_none(), "one failure is not a storm");
        for i in 2..=FAULT_STORM_THRESHOLD as u64 {
            sink.record_span(&span(i, 0.2 + i as f64 * 0.01, SpanPoint::Failed { generated: 0 }));
        }
        let (reason, _) = sink.take_dump().expect("fault storm arms a dump");
        assert!(reason.contains("fault-storm"), "got: {reason}");

        let mut quiet = TraceSink::default();
        for i in 0..2 * FAULT_STORM_THRESHOLD {
            let t = i as f64 * 10.0;
            quiet.record_span(&span(i as u64, t, SpanPoint::Failed { generated: 0 }));
        }
        assert!(quiet.take_dump().is_none(), "spread-out failures are not a storm");
    }

    #[test]
    fn shutdown_dump_is_armable_once() {
        let mut sink = TraceSink::default();
        sink.request_dump("shutdown");
        sink.request_dump("later");
        let (reason, _) = sink.take_dump().unwrap();
        assert_eq!(reason, "shutdown");
    }

    #[test]
    fn chrome_trace_has_one_complete_span_per_closed_request() {
        let mut sink = TraceSink::default();
        sink.record_step(&step(0, 0.0));
        sink.record_step(&step(1, 0.002));
        sink.record_span(&span(7, 0.0, SpanPoint::Queued));
        sink.record_span(&span(7, 0.001, SpanPoint::Admitted { lane: 0 }));
        sink.record_span(&span(7, 0.004, SpanPoint::FirstToken));
        sink.record_span(&span(7, 0.01, SpanPoint::Done { generated: 3 }));
        sink.record_span(&span(8, 0.0, SpanPoint::Queued)); // still open

        let Json::Obj(root) = sink.chrome_trace() else { panic!("object root") };
        let Json::Arr(events) = &root["traceEvents"] else { panic!("traceEvents array") };
        let request_spans: Vec<&Json> = events
            .iter()
            .filter(|e| {
                e.get("cat") == Some(&Json::Str("request".into()))
                    && e.get("ph") == Some(&Json::Str("X".into()))
            })
            .collect();
        assert_eq!(request_spans.len(), 1, "open spans are not exported");
        let Json::Obj(req) = request_spans[0] else { panic!() };
        assert_eq!(req["tid"], Json::Num(7.0));
        assert_eq!(req["ts"], Json::Num(0.0));
        assert_eq!(req["dur"], Json::Num(0.01 * 1e6));
        let steps: Vec<&Json> = events
            .iter()
            .filter(|e| e.get("cat") == Some(&Json::Str("step".into())))
            .collect();
        assert_eq!(steps.len(), 2);
    }
}
