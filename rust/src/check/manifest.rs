//! Manifest geometry checks (`CLV001`–`CLV016`).
//!
//! This walks the *raw* `manifest.json` document rather than reusing
//! [`Manifest::load`]: the loader stops at the first structural problem,
//! while a checker must keep going and report everything it can see.
//! Cross-validated, per config entry:
//!
//! * the rank ladder is non-empty, strictly monotonic (the exporter
//!   writes it descending; either direction is fine), and inside
//!   `1..=d_head`, and every advertised rank has both its factorized
//!   param spec and its `decode_fac_r{r}_b{B}` program for every decode
//!   batch (the rank family the router and the speculative draft builder
//!   select from);
//! * the prefill chunk ladder is strictly increasing with widths `>= 2`,
//!   every advertised chunk has an exported `prefill_k{K}_b{B}` slab
//!   program, and every exported slab width is advertised (the engine
//!   plans only over `prefill_chunks` — an unadvertised artifact is dead
//!   weight, flagged as a warning);
//! * `verify_widths` is a subset of the chunk ladder and each verify
//!   program really emits all-position `[B, K, V]` logits over `[B, K]`
//!   token slabs (the speculative-verify contract);
//! * prefill and decode programs of the same batch agree on the cache
//!   block (the runtime carries one literal-side cache set across the
//!   whole width family);
//! * every dtype in every program signature is one the runtime supports,
//!   and (with [`ManifestCheckOpts::check_files`]) every program's HLO
//!   file exists on disk.

use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

use crate::config::json::Json;
use crate::model::manifest::DType;
use crate::model::Manifest;

use super::diag::Report;

#[derive(Clone, Debug, Default)]
pub struct ManifestCheckOpts {
    /// Also require each program's HLO file to exist under the artifacts
    /// dir (`CLV016`).  Off by default so manifest-only fixtures and
    /// checked-in manifests without their artifacts stay checkable.
    pub check_files: bool,
}

/// Dim keys a decoder config must carry (the serve/speculative paths read
/// all of these); seq2seq configs have their own set.
const DECODER_DIMS: &[&str] = &["vocab", "d_model", "n_heads", "n_layers", "seq_len", "d_head"];
const SEQ2SEQ_DIMS: &[&str] =
    &["vocab", "d_model", "n_heads", "n_enc_layers", "n_dec_layers", "d_head", "feat_dim"];

/// One program signature, leniently parsed.
struct RawSig {
    file: String,
    inputs: Vec<RawArg>,
    outputs: Vec<RawArg>,
}

struct RawArg {
    name: String,
    shape: Vec<usize>,
    dtype: String,
}

fn parse_args(v: &Json) -> Result<Vec<RawArg>, String> {
    let mut out = Vec::new();
    for (i, e) in v.as_arr().map_err(|e| e.to_string())?.iter().enumerate() {
        let name = e
            .req("name")
            .and_then(|n| n.as_str().map(String::from))
            .map_err(|e| format!("arg {i}: {e}"))?;
        let shape = e.req("shape").and_then(|s| s.as_shape()).map_err(|e| format!("{name}: {e}"))?;
        let dtype = e
            .req("dtype")
            .and_then(|d| d.as_str().map(String::from))
            .map_err(|e| format!("{name}: {e}"))?;
        out.push(RawArg { name, shape, dtype });
    }
    Ok(out)
}

fn parse_sig(v: &Json) -> Result<RawSig, String> {
    let file =
        v.req("file").and_then(|f| f.as_str().map(String::from)).map_err(|e| e.to_string())?;
    let inputs = parse_args(v.req("inputs").map_err(|e| e.to_string())?)?;
    let outputs = parse_args(v.req("outputs").map_err(|e| e.to_string())?)?;
    Ok(RawSig { file, inputs, outputs })
}

/// `prefill_k8_b8` / `prefill_fac_r4_k8_b8` → `(width 8, batch 8)`.
fn slab_geometry(name: &str) -> Option<(usize, usize)> {
    let rest = name.strip_prefix("prefill")?;
    let (head, b) = rest.rsplit_once("_b")?;
    let (_, k) = head.rsplit_once("_k")?;
    Some((k.parse().ok()?, b.parse().ok()?))
}

/// `decode_b8` → batch 8 (the dense decode family defines the batch set).
fn decode_batch(name: &str) -> Option<usize> {
    name.strip_prefix("decode_b")?.parse().ok()
}

fn cache_input(sig: &RawSig) -> Option<&RawArg> {
    sig.inputs.iter().find(|a| a.name.ends_with("_cache"))
}

/// Check `dir/manifest.json`.  Returns the typed [`Manifest`] when it is
/// loadable at all (geometry findings do not block the typed view — the
/// serve checks still want it), `None` when even the loader rejects it.
pub fn check_manifest_dir(
    report: &mut Report,
    dir: &Path,
    opts: &ManifestCheckOpts,
) -> Option<Manifest> {
    let path = dir.join("manifest.json");
    let label = path.display().to_string();
    let text = match std::fs::read_to_string(&path) {
        Ok(t) => t,
        Err(e) => {
            report.push(
                1,
                &label,
                "$",
                format!("cannot read the manifest: {e}"),
                "run `make artifacts` (python -m compile.aot) to export it",
            );
            return None;
        }
    };
    let doc = match Json::parse(&text) {
        Ok(d) => d,
        Err(e) => {
            report.push(2, &label, "$", format!("not valid JSON: {e}"), "re-export the artifacts");
            return None;
        }
    };
    let Some(configs) = doc.get("configs").and_then(|c| c.as_obj().ok()) else {
        report.push(
            3,
            &label,
            "$.configs",
            "manifest has no `configs` object".to_string(),
            "re-export the artifacts — the exporter always writes `configs`",
        );
        return None;
    };
    for (name, entry) in configs {
        check_config_entry(report, &label, dir, name, entry, opts);
    }
    Manifest::load(dir).ok()
}

fn check_config_entry(
    report: &mut Report,
    label: &str,
    dir: &Path,
    name: &str,
    entry: &Json,
    opts: &ManifestCheckOpts,
) {
    let at = |field: &str| format!("$.configs.{name}.{field}");
    let reexport = "re-export the artifacts with `python -m compile.aot`";

    // -- kind + dims ------------------------------------------------------
    let kind = match entry.req("kind").and_then(|k| k.as_str()) {
        Ok(k) => Some(k.to_string()),
        Err(e) => {
            report.push(4, label, &at("kind"), e.to_string(), reexport);
            None
        }
    };
    let dim = |key: &str| entry.get(key).and_then(|v| v.as_usize().ok());
    let required: &[&str] = match kind.as_deref() {
        Some("decoder") => DECODER_DIMS,
        Some("seq2seq") => SEQ2SEQ_DIMS,
        _ => &[],
    };
    for key in required {
        if dim(key).is_none() {
            report.push(
                5,
                label,
                &at(key),
                format!("{} config {name} is missing dim {key}", kind.as_deref().unwrap_or("?")),
                reexport,
            );
        }
    }
    let d_head = dim("d_head");
    let vocab = dim("vocab");

    // -- rank ladder ------------------------------------------------------
    let ranks = match entry.req("ranks").and_then(|r| r.as_shape()) {
        Ok(r) => r,
        Err(e) => {
            report.push(4, label, &at("ranks"), e.to_string(), reexport);
            Vec::new()
        }
    };
    if entry.get("ranks").is_some() {
        if ranks.is_empty() {
            report.push(6, label, &at("ranks"), "rank ladder is empty".to_string(), reexport);
        }
        if ranks.contains(&0) {
            report.push(6, label, &at("ranks"), "rank 0 is not a rank".to_string(), reexport);
        }
        // The exporter writes the grid dense-first (descending); hand-written
        // manifests often sort ascending.  Everything downstream treats the
        // ladder as a set, so either strict order is fine — what CLV006
        // rejects is a shuffled or duplicated ladder.
        let increasing = ranks.windows(2).all(|w| w[0] < w[1]);
        let decreasing = ranks.windows(2).all(|w| w[0] > w[1]);
        if !increasing && !decreasing {
            report.push(
                6,
                label,
                &at("ranks"),
                format!("rank ladder {ranks:?} is not strictly monotonic (shuffled or duplicated)"),
                reexport,
            );
        }
        if let (Some(&max), Some(dh)) = (ranks.iter().max(), d_head) {
            if max > dh {
                report.push(
                    6,
                    label,
                    &at("ranks"),
                    format!("rank {max} exceeds d_head {dh} — no orthogonal basis that wide"),
                    reexport,
                );
            }
        }
    }

    // -- programs ---------------------------------------------------------
    let mut programs: BTreeMap<String, RawSig> = BTreeMap::new();
    match entry.req("programs").and_then(|p| p.as_obj()) {
        Ok(progs) => {
            for (pname, sig) in progs {
                match parse_sig(sig) {
                    Ok(s) => {
                        for arg in s.inputs.iter().chain(&s.outputs) {
                            if DType::parse(&arg.dtype).is_err() {
                                report.push(
                                    15,
                                    label,
                                    &format!("{}.{pname}", at("programs")),
                                    format!(
                                        "arg {} has dtype {:?} — the runtime only marshals \
                                         float32/int32",
                                        arg.name, arg.dtype
                                    ),
                                    reexport,
                                );
                            }
                        }
                        if opts.check_files && !dir.join(&s.file).is_file() {
                            report.push(
                                16,
                                label,
                                &format!("{}.{pname}", at("programs")),
                                format!("program file {:?} is missing on disk", s.file),
                                "re-export the artifacts or drop the stale manifest entry",
                            );
                        }
                        programs.insert(pname.clone(), s);
                    }
                    Err(e) => {
                        report.push(4, label, &format!("{}.{pname}", at("programs")), e, reexport);
                    }
                }
            }
        }
        Err(e) => report.push(4, label, &at("programs"), e.to_string(), reexport),
    }
    if kind.as_deref() != Some("decoder") {
        return; // the serving-path geometry below is decoder-only
    }

    // -- rank family completeness ----------------------------------------
    let decode_batches: BTreeSet<usize> =
        programs.keys().filter_map(|n| decode_batch(n)).collect();
    let fac_ranks: BTreeSet<usize> = match entry.get("params_fac").map(|p| p.as_obj()) {
        Some(Ok(obj)) => obj.keys().filter_map(|k| k.parse().ok()).collect(),
        Some(Err(e)) => {
            report.push(4, label, &at("params_fac"), e.to_string(), reexport);
            BTreeSet::new()
        }
        None => BTreeSet::new(),
    };
    for &r in &ranks {
        if !fac_ranks.contains(&r) {
            report.push(
                7,
                label,
                &at("params_fac"),
                format!("advertised rank {r} has no factorized param spec"),
                reexport,
            );
        }
        for &b in &decode_batches {
            let want = format!("decode_fac_r{r}_b{b}");
            if !programs.contains_key(&want) {
                report.push(
                    8,
                    label,
                    &at("ranks"),
                    format!("advertised rank {r} lacks its decode program {want:?}"),
                    reexport,
                );
            }
        }
    }

    // -- prefill chunk ladder --------------------------------------------
    let chunks = match entry.get("prefill_chunks").map(|v| v.as_shape()) {
        Some(Ok(c)) => c,
        Some(Err(e)) => {
            report.push(4, label, &at("prefill_chunks"), e.to_string(), reexport);
            Vec::new()
        }
        None => Vec::new(),
    };
    if chunks.iter().any(|&k| k < 2) || chunks.windows(2).any(|w| w[0] >= w[1]) {
        report.push(
            9,
            label,
            &at("prefill_chunks"),
            format!("chunk ladder {chunks:?} must be strictly increasing widths >= 2"),
            reexport,
        );
    }
    let exported: BTreeSet<(usize, usize)> =
        programs.keys().filter_map(|n| slab_geometry(n)).collect();
    for &k in &chunks {
        if !exported.iter().any(|&(w, _)| w == k) {
            report.push(
                10,
                label,
                &at("prefill_chunks"),
                format!("advertised chunk {k} has no prefill_k{k}_b* slab program"),
                reexport,
            );
        }
    }
    for &(w, b) in &exported {
        if !chunks.contains(&w) {
            report.push(
                11,
                label,
                &at("prefill_chunks"),
                format!(
                    "slab program for width {w} (batch {b}) is exported but not advertised — \
                     the engine will never schedule it"
                ),
                "add the width to prefill_chunks or stop exporting it",
            );
        }
    }

    // -- verify widths ----------------------------------------------------
    let verify = match entry.get("verify_widths").map(|v| v.as_shape()) {
        Some(Ok(v)) => v,
        Some(Err(e)) => {
            report.push(4, label, &at("verify_widths"), e.to_string(), reexport);
            Vec::new()
        }
        None => Vec::new(),
    };
    for &w in &verify {
        if !chunks.contains(&w) {
            report.push(
                12,
                label,
                &at("verify_widths"),
                format!("verify width {w} is not in prefill_chunks {chunks:?}"),
                reexport,
            );
        }
    }
    for &w in &verify {
        let of_width = programs.iter().filter(|(n, _)| is_slab_of_width(n, w));
        for (pname, sig) in of_width {
            let locus = format!("{}.{pname}", at("programs"));
            check_verify_sig(report, label, &locus, pname, sig, w, vocab);
        }
    }

    // -- cache block agreement -------------------------------------------
    for (pname, sig) in &programs {
        let Some((_, b)) = slab_geometry(pname) else { continue };
        // The dense slab family shares its cache with `decode_b{b}`; the
        // factorized families with `decode_fac_r{r}_b{b}` — compare
        // against whichever sibling exists.
        let sibling = match pname.strip_prefix("prefill_fac_") {
            Some(rest) => rest
                .split_once("_k")
                .map(|(r, _)| format!("decode_fac_r{r}_b{b}"))
                .unwrap_or_default(),
            None => format!("decode_b{b}"),
        };
        let Some(dec) = programs.get(&sibling) else { continue };
        let (pc, dc) = (cache_input(sig), cache_input(dec));
        if let (Some(pc), Some(dc)) = (pc, dc) {
            if pc.shape != dc.shape {
                report.push(
                    14,
                    label,
                    &format!("{}.{pname}", at("programs")),
                    format!(
                        "cache block {:?} disagrees with {sibling}'s {:?} — the runtime \
                         carries one cache set across the width family",
                        pc.shape, dc.shape
                    ),
                    reexport,
                );
            }
        }
    }
}

/// Is `name` a slab program of width `w` (any batch, any rank family)?
fn is_slab_of_width(name: &str, w: usize) -> bool {
    slab_geometry(name).is_some_and(|(k, _)| k == w)
}

/// The speculative-verify contract for one slab program: `[B, K]` tokens
/// in, `[B, K, V]` logits out.
fn check_verify_sig(
    report: &mut Report,
    label: &str,
    locus: &str,
    pname: &str,
    sig: &RawSig,
    w: usize,
    vocab: Option<usize>,
) {
    let b = slab_geometry(pname).map(|(_, b)| b).unwrap_or(0);
    let reexport = "re-export the artifacts — stale slab programs predate all-position logits";
    if let Some(toks) = sig.inputs.iter().find(|a| a.name == "tokens") {
        if toks.shape != [b, w] {
            report.push(
                13,
                label,
                locus,
                format!("{pname}: tokens {:?} is not the [B, K] slab [{b}, {w}]", toks.shape),
                reexport,
            );
        }
    }
    match sig.outputs.first() {
        Some(lg) if lg.shape.len() == 3 => {
            let want_v = vocab.unwrap_or(lg.shape[2]);
            if lg.shape != [b, w, want_v] {
                report.push(
                    13,
                    label,
                    locus,
                    format!(
                        "{pname}: logits {:?} disagree with [B, K, V] = [{b}, {w}, {want_v}]",
                        lg.shape
                    ),
                    reexport,
                );
            }
        }
        Some(lg) => {
            report.push(
                13,
                label,
                locus,
                format!(
                    "{pname}: logits {:?} are last-position only — a verify step cannot \
                     score a draft with them",
                    lg.shape
                ),
                reexport,
            );
        }
        None => {
            report.push(13, label, locus, format!("{pname}: no outputs at all"), reexport);
        }
    }
}
