//! Diagnostic records, the `CLV0xx` code catalog, and the renderers.
//!
//! Every finding `clover check` can emit is a [`Diagnostic`]: a stable
//! numeric code (rendered `CLV0xx`), a severity fixed by the catalog, the
//! file it was found in, a locus inside that file (a JSON-pointer-style
//! path like `$.configs.tiny.prefill_chunks`, or the CLI flag that
//! carried the bad value), a human message, and a fix hint.  Codes are
//! append-only: once a code has shipped in a golden file or a CI log its
//! meaning never changes — new failure modes get new codes.
//!
//! [`Report`] collects diagnostics across all checked documents, sorts
//! them deterministically, and renders them as `--format text`, `--format
//! json`, or the compact [`Report::golden_lines`] form the fixture tests
//! assert against (code + severity + locus only, so goldens survive
//! message rewording).

use std::collections::BTreeMap;

use crate::config::json::Json;

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Error,
}

impl Severity {
    pub fn as_str(self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// One catalog row: the stable code, its fixed severity, and a one-line
/// title (the documentation anchor — `docs/STATIC_ANALYSIS.md` lists every
/// row, enforced by a test in this module).
pub struct CatalogEntry {
    pub code: u16,
    pub severity: Severity,
    pub title: &'static str,
}

const E: Severity = Severity::Error;
const W: Severity = Severity::Warning;

/// The full `CLV0xx` catalog.  Grouped: 001–016 manifest geometry,
/// 020–039 serve/engine-spec combinations (037–039 are the chaos /
/// robustness flags), 040–045 bench documents.
pub const CATALOG: &[CatalogEntry] = &[
    CatalogEntry { code: 1, severity: E, title: "artifacts manifest unreadable" },
    CatalogEntry { code: 2, severity: E, title: "manifest is not valid JSON" },
    CatalogEntry { code: 3, severity: E, title: "manifest has no `configs` object" },
    CatalogEntry { code: 4, severity: E, title: "malformed config entry" },
    CatalogEntry { code: 5, severity: E, title: "config is missing a required dimension" },
    CatalogEntry { code: 6, severity: E, title: "rank ladder malformed" },
    CatalogEntry { code: 7, severity: E, title: "advertised rank has no factorized param spec" },
    CatalogEntry { code: 8, severity: E, title: "advertised rank lacks its decode program" },
    CatalogEntry { code: 9, severity: E, title: "prefill chunk ladder malformed" },
    CatalogEntry { code: 10, severity: E, title: "advertised prefill chunk lacks its slab program" },
    CatalogEntry { code: 11, severity: W, title: "exported slab width not advertised" },
    CatalogEntry { code: 12, severity: E, title: "verify_widths is not a prefix-closed subset" },
    CatalogEntry { code: 13, severity: E, title: "verify width lacks all-position logits" },
    CatalogEntry { code: 14, severity: E, title: "prefill/decode cache blocks disagree" },
    CatalogEntry { code: 15, severity: E, title: "unsupported dtype in a program signature" },
    CatalogEntry { code: 16, severity: W, title: "program file missing on disk" },
    CatalogEntry { code: 20, severity: E, title: "preset not found in the manifest" },
    CatalogEntry { code: 21, severity: E, title: "KV layer-budget count mismatches the layers" },
    CatalogEntry { code: 22, severity: E, title: "KV layer budget outside 1..=rank" },
    CatalogEntry { code: 23, severity: E, title: "KV codec spec unparsable" },
    CatalogEntry { code: 24, severity: E, title: "engine rank incompatible with the geometry" },
    CatalogEntry { code: 25, severity: E, title: "speculative draft length below the minimum" },
    CatalogEntry { code: 26, severity: E, title: "speculation needs a chunked verify ladder" },
    CatalogEntry { code: 27, severity: E, title: "speculation requires greedy sampling" },
    CatalogEntry { code: 28, severity: W, title: "max-step-tokens starves the chunk ladder" },
    CatalogEntry { code: 29, severity: E, title: "KV memory budget admits no request at all" },
    CatalogEntry { code: 30, severity: W, title: "KV memory budget below one full window" },
    CatalogEntry { code: 31, severity: E, title: "run config unreadable or unparsable" },
    CatalogEntry { code: 32, severity: E, title: "run config failed validation" },
    CatalogEntry { code: 33, severity: W, title: "run config references absent geometry" },
    CatalogEntry { code: 34, severity: E, title: "prefix cache block misaligned with pages or ladder" },
    CatalogEntry { code: 35, severity: E, title: "prefix cache illegal beside a speculative pair" },
    CatalogEntry { code: 36, severity: W, title: "prefix cache without a workable eviction budget" },
    CatalogEntry { code: 37, severity: E, title: "fault plan spec violates the schema" },
    CatalogEntry { code: 38, severity: E, title: "circuit-breaker thresholds out of order" },
    CatalogEntry { code: 39, severity: W, title: "retry backoff cannot finish inside the deadline" },
    CatalogEntry { code: 40, severity: E, title: "bench document unreadable or unparsable" },
    CatalogEntry { code: 41, severity: E, title: "bench document shape unrecognized" },
    CatalogEntry { code: 42, severity: E, title: "bench document missing a required key" },
    CatalogEntry { code: 43, severity: E, title: "bench document has a non-finite number" },
    CatalogEntry { code: 44, severity: E, title: "bench invariant violated" },
    CatalogEntry { code: 45, severity: W, title: "bench metric is a null bootstrap placeholder" },
];

/// Catalog lookup; `None` for an unregistered code (a checker bug — the
/// `Report::push` path asserts against it in debug builds).
pub fn catalog_entry(code: u16) -> Option<&'static CatalogEntry> {
    CATALOG.iter().find(|e| e.code == code)
}

/// One finding.
#[derive(Clone, Debug)]
pub struct Diagnostic {
    pub code: u16,
    pub severity: Severity,
    /// The file (or pseudo-file like `<flags>`) the finding is about.
    pub path: String,
    /// Locus inside the file: `$.configs.tiny.ranks`, `--draft-rank`, ...
    pub locus: String,
    pub message: String,
    /// One-line fix suggestion; empty when there is nothing actionable.
    pub hint: String,
}

impl Diagnostic {
    pub fn code_str(&self) -> String {
        format!("CLV{:03}", self.code)
    }
}

/// Accumulates diagnostics across every checked document.
#[derive(Default)]
pub struct Report {
    diags: Vec<Diagnostic>,
}

impl Report {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record a finding.  Severity comes from the catalog — call sites
    /// cannot disagree with the documented meaning of a code.
    pub fn push(&mut self, code: u16, path: &str, locus: &str, message: String, hint: &str) {
        let severity = match catalog_entry(code) {
            Some(e) => e.severity,
            None => {
                debug_assert!(false, "diagnostic code {code} is not in the catalog");
                Severity::Error
            }
        };
        self.diags.push(Diagnostic {
            code,
            severity,
            path: path.to_string(),
            locus: locus.to_string(),
            message,
            hint: hint.to_string(),
        });
    }

    /// Deterministic order: by file, then code, then locus — golden files
    /// and CI logs are stable under checker-internal reordering.
    pub fn sort(&mut self) {
        self.diags.sort_by(|a, b| (&a.path, a.code, &a.locus).cmp(&(&b.path, b.code, &b.locus)));
    }

    pub fn diagnostics(&self) -> &[Diagnostic] {
        &self.diags
    }

    pub fn is_empty(&self) -> bool {
        self.diags.is_empty()
    }

    pub fn error_count(&self) -> usize {
        self.diags.iter().filter(|d| d.severity == Severity::Error).count()
    }

    pub fn warning_count(&self) -> usize {
        self.diags.iter().filter(|d| d.severity == Severity::Warning).count()
    }

    pub fn has_errors(&self) -> bool {
        self.error_count() > 0
    }

    /// `--format text`: one block per finding plus a summary line.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for d in &self.diags {
            out.push_str(&format!(
                "{} {} {} {}: {}\n",
                d.code_str(),
                d.severity.as_str(),
                d.path,
                d.locus,
                d.message
            ));
            if !d.hint.is_empty() {
                out.push_str(&format!("  hint: {}\n", d.hint));
            }
        }
        out.push_str(&format!(
            "{} error(s), {} warning(s)\n",
            self.error_count(),
            self.warning_count()
        ));
        out
    }

    /// `--format json`: machine-readable dump of every field.
    pub fn to_json(&self) -> Json {
        let diags = self
            .diags
            .iter()
            .map(|d| {
                let mut m = BTreeMap::new();
                m.insert("code".to_string(), Json::Str(d.code_str()));
                m.insert("severity".to_string(), Json::Str(d.severity.as_str().to_string()));
                m.insert("path".to_string(), Json::Str(d.path.clone()));
                m.insert("locus".to_string(), Json::Str(d.locus.clone()));
                m.insert("message".to_string(), Json::Str(d.message.clone()));
                m.insert("hint".to_string(), Json::Str(d.hint.clone()));
                Json::Obj(m)
            })
            .collect();
        let mut top = BTreeMap::new();
        top.insert("diagnostics".to_string(), Json::Arr(diags));
        top.insert("errors".to_string(), Json::Num(self.error_count() as f64));
        top.insert("warnings".to_string(), Json::Num(self.warning_count() as f64));
        Json::Obj(top)
    }

    /// Compact `CODE severity locus` lines for the golden fixture tests.
    /// Messages and file paths are deliberately excluded: goldens stay
    /// stable under rewording and fixture relocation, while still pinning
    /// *which* code fires *where* in the document.
    pub fn golden_lines(&self) -> String {
        let mut out = String::new();
        for d in &self.diags {
            out.push_str(&format!("{} {} {}\n", d.code_str(), d.severity.as_str(), d.locus));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_codes_unique_and_sorted() {
        for w in CATALOG.windows(2) {
            assert!(w[0].code < w[1].code, "catalog out of order at {}", w[1].code);
        }
    }

    #[test]
    fn push_takes_severity_from_catalog() {
        let mut r = Report::new();
        r.push(11, "m.json", "$.x", "unadvertised".into(), "");
        r.push(9, "m.json", "$.y", "bad ladder".into(), "re-export");
        assert_eq!(r.diagnostics()[0].severity, Severity::Warning);
        assert_eq!(r.diagnostics()[1].severity, Severity::Error);
        assert_eq!(r.error_count(), 1);
        assert_eq!(r.warning_count(), 1);
        assert!(r.has_errors());
    }

    #[test]
    fn sort_is_by_path_code_locus() {
        let mut r = Report::new();
        r.push(9, "b.json", "$.z", String::new(), "");
        r.push(9, "a.json", "$.z", String::new(), "");
        r.push(6, "b.json", "$.a", String::new(), "");
        r.sort();
        let order: Vec<(&str, u16)> =
            r.diagnostics().iter().map(|d| (d.path.as_str(), d.code)).collect();
        assert_eq!(order, vec![("a.json", 9), ("b.json", 6), ("b.json", 9)]);
    }

    #[test]
    fn text_render_carries_code_and_hint() {
        let mut r = Report::new();
        r.push(10, "m.json", "$.configs.tiny", "missing prefill_k8_b8".into(), "re-export");
        let text = r.render_text();
        assert!(text.contains("CLV010 error m.json $.configs.tiny: missing prefill_k8_b8"));
        assert!(text.contains("hint: re-export"));
        assert!(text.contains("1 error(s), 0 warning(s)"));
    }

    #[test]
    fn json_render_is_parseable_and_counts() {
        let mut r = Report::new();
        r.push(45, "BENCH_serve.json", "$.obs", "null".into(), "");
        let j = r.to_json();
        let back = Json::parse(&crate::config::json::to_string(&j)).unwrap();
        assert_eq!(back.req("warnings").unwrap().as_usize().unwrap(), 1);
        assert_eq!(back.req("errors").unwrap().as_usize().unwrap(), 0);
        let arr = back.req("diagnostics").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].req("code").unwrap().as_str().unwrap(), "CLV045");
    }

    #[test]
    fn golden_lines_exclude_path_and_message() {
        let mut r = Report::new();
        r.push(12, "/tmp/anywhere/manifest.json", "$.configs.tiny.verify_widths", "x".into(), "");
        assert_eq!(r.golden_lines(), "CLV012 error $.configs.tiny.verify_widths\n");
    }

    /// Every catalog code must be documented in docs/STATIC_ANALYSIS.md —
    /// the error-code catalog and the checker can never drift apart.
    #[test]
    fn catalog_is_documented() {
        let doc_path =
            std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("../docs/STATIC_ANALYSIS.md");
        let doc = std::fs::read_to_string(&doc_path)
            .unwrap_or_else(|e| panic!("reading {doc_path:?}: {e}"));
        for e in CATALOG {
            let code = format!("CLV{:03}", e.code);
            assert!(doc.contains(&code), "{code} ({}) missing from STATIC_ANALYSIS.md", e.title);
        }
    }
}
