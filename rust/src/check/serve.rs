//! Engine-spec and run-config checks (`CLV020`–`CLV039`).
//!
//! [`ServeSpec`] is the static mirror of the flag surface an engine spawn
//! consumes (`clover serve`, `EngineSpec`, the gateway worker): preset,
//! batch slots, chunk-ladder cap, speculative draft pair, KV codec +
//! budgets, per-step token budget, prefix-cache block, and the chaos /
//! robustness flags (fault plan, retry policy, circuit-breaker
//! thresholds).  [`check_engine_spec`] cross-validates
//! the combination against the manifest *before* anything spawns — the
//! same rules the engine builders enforce with `bail!` at construction,
//! surfaced as diagnostics with stable codes instead of a panic-shaped
//! log line deep inside a worker thread.
//!
//! [`check_run_config`] covers committed `*.toml` run configs: parse +
//! [`RunConfig::validate`] failures, plus cross-references against the
//! manifest (preset exists, `serve.kv_rank` is an exported rank).

use crate::config::RunConfig;
use crate::model::Manifest;
use crate::runtime::stub::FaultPlan;
use crate::serve::kv::{KvSpecError, PAGE_TOKENS};
use crate::serve::{KvCodecSpec, KvConfig, RetryPolicy, SpecConfig};

use super::diag::Report;

/// Static image of one engine-spawn flag combination.
#[derive(Clone, Debug)]
pub struct ServeSpec {
    pub preset: String,
    /// Micro-batch lanes (`decode_b{B}` programs; the CLI serves at 8).
    pub batch_slots: usize,
    /// Target engine rank (`None` = dense, i.e. rank `d_head`).
    pub rank: Option<usize>,
    /// `--prefill-chunk` ladder cap (`None` keeps every exported width).
    pub prefill_chunk: Option<usize>,
    /// `--max-step-tokens` fused-step budget.
    pub max_step_tokens: Option<usize>,
    pub kv_codec: KvCodecSpec,
    /// `--kv-memory-budget` admission budget in bytes.
    pub kv_memory_budget: Option<usize>,
    /// `--speculative`: draft rank + draft-length config.
    pub speculative: Option<(usize, SpecConfig)>,
    /// `--temperature` (speculation is greedy-only).
    pub temperature: f64,
    /// `--prefix-cache-block`: radix prefix cache block size in tokens
    /// (`None` = cache off).
    pub prefix_cache_block: Option<usize>,
    /// `--fault-plan` spec string, unparsed (`None` = no injection armed).
    pub fault_plan: Option<String>,
    /// `--retry-budget`: transient-step retries after the first attempt.
    pub retry_budget: usize,
    /// `--retry-backoff-ms`: base backoff, doubled each retry.
    pub retry_backoff_ms: u64,
    /// `--breaker-degraded` / `--breaker-open` EWMA thresholds
    /// (`None` = router breaker left at defaults, nothing to validate).
    pub breaker: Option<(f64, f64)>,
    /// `--deadline-ms` per-request deadline (feasibility input for the
    /// retry-backoff check; `None` = requests never expire).
    pub deadline_ms: Option<u64>,
}

impl Default for ServeSpec {
    fn default() -> Self {
        Self {
            preset: "tiny".to_string(),
            batch_slots: 8,
            rank: None,
            prefill_chunk: None,
            max_step_tokens: None,
            kv_codec: KvCodecSpec::Identity,
            kv_memory_budget: None,
            speculative: None,
            temperature: 0.0,
            prefix_cache_block: None,
            fault_plan: None,
            retry_budget: RetryPolicy::default().budget,
            retry_backoff_ms: RetryPolicy::default().backoff.as_millis() as u64,
            breaker: None,
            deadline_ms: None,
        }
    }
}

/// Validate `spec` against `manifest`.  `label` names the source of the
/// flags in the diagnostics (`<flags>` for the CLI, a config path when
/// the spec came from a file); loci are the flags themselves.
pub fn check_engine_spec(report: &mut Report, manifest: &Manifest, spec: &ServeSpec, label: &str) {
    // -- chaos / robustness flags (CLV037–CLV039) -------------------------
    // Validated before the manifest lookup: none of these need geometry,
    // and a typo'd fault plan should surface even against a bad preset.
    if let Some(plan) = &spec.fault_plan {
        if let Err(e) = FaultPlan::parse(plan) {
            report.push(
                37,
                label,
                "--fault-plan",
                e.to_string(),
                "keys: seed, transient, spike, spike-factor, poison, fatal-after, crash-after \
                 (rates in 0..=1); or `off`",
            );
        }
    }
    if let Some((degraded, open)) = spec.breaker {
        // Negated comparison (not `||` of violations) so a NaN threshold
        // also fails: the EWMA must walk Healthy → Degraded → Open.
        if !(degraded > 0.0 && degraded < open && open <= 1.0) {
            report.push(
                38,
                label,
                "--breaker-open",
                format!(
                    "breaker thresholds must satisfy 0 < degraded ({degraded}) < open \
                     ({open}) <= 1 — the fault-rate EWMA walks Healthy → Degraded → Open \
                     in that order"
                ),
                "e.g. --breaker-degraded 0.1 --breaker-open 0.5",
            );
        }
    }
    if let Some(deadline) = spec.deadline_ms {
        if spec.retry_budget > 0 {
            // Worst-case backoff burned before the engine gives up on a
            // transient storm: base × (2^budget − 1), saturating — a
            // budget past 63 doublings is past any real deadline anyway.
            let doublings =
                1u64.checked_shl(spec.retry_budget as u32).map_or(u64::MAX, |v| v - 1);
            let worst = spec.retry_backoff_ms.saturating_mul(doublings);
            if worst >= deadline {
                report.push(
                    39,
                    label,
                    "--retry-budget",
                    format!(
                        "a transient storm burns up to {worst} ms of backoff ({} retries \
                         doubling from {} ms) before the engine gives up — at or past the \
                         {deadline} ms request deadline, a retried request expires \
                         mid-backoff instead of recovering",
                        spec.retry_budget, spec.retry_backoff_ms
                    ),
                    "shrink --retry-budget/--retry-backoff-ms or raise --deadline-ms",
                );
            }
        }
    }

    let Ok(entry) = manifest.config(&spec.preset) else {
        report.push(
            20,
            label,
            "--preset",
            format!(
                "preset {:?} is not in the manifest (have: {:?})",
                spec.preset,
                manifest.configs.keys().collect::<Vec<_>>()
            ),
            "export the preset or fix the name",
        );
        return;
    };
    // Geometry the rest of the checks hang off; a manifest that lost one
    // of these dims is already flagged (CLV005) by the manifest pass.
    let dims = (
        entry.dim("n_layers").ok(),
        entry.dim("n_heads").ok(),
        entry.dim("d_head").ok(),
        entry.dim("seq_len").ok(),
    );
    let (Some(n_layers), Some(n_heads), Some(d_head), Some(seq_len)) = dims else {
        return;
    };
    let rank = spec.rank.unwrap_or(d_head);
    if spec.rank.is_some_and(|r| !entry.ranks.contains(&r)) {
        report.push(
            24,
            label,
            "--rank",
            format!("rank {rank} is not an exported rank (ladder {:?})", entry.ranks),
            "pick a rank from the manifest's ladder",
        );
    }

    // -- KV codec vs geometry --------------------------------------------
    let stored = match spec.kv_codec.resolve(n_layers, rank) {
        Ok(s) => Some(s),
        Err(e @ KvSpecError::BudgetLen { .. }) => {
            report.push(
                21,
                label,
                "--kv-layer-budgets",
                e.to_string(),
                "pass exactly one budget per manifest layer",
            );
            None
        }
        Err(e @ KvSpecError::BudgetRange { .. }) => {
            report.push(
                22,
                label,
                "--kv-layer-budgets",
                e.to_string(),
                "budgets are per-layer stored ranks in 1..=rank",
            );
            None
        }
        Err(e) => {
            report.push(23, label, "--kv-codec", e.to_string(), "see --kv-codec in the CLI help");
            None
        }
    };

    // -- slab ladder under the --prefill-chunk cap ------------------------
    let mut widths: Vec<usize> = entry.prefill_chunks.clone();
    if let Some(cap) = spec.prefill_chunk {
        widths.retain(|&w| w <= cap);
    }
    let max_chunk = widths.last().copied().unwrap_or(1);

    // -- speculative pair -------------------------------------------------
    if let Some((draft_rank, cfg)) = &spec.speculative {
        if cfg.draft_len < 2 {
            report.push(
                25,
                label,
                "--draft-len",
                format!("draft_len {} cannot beat one fused step per token", cfg.draft_len),
                "use a draft length >= 2",
            );
        }
        if max_chunk < 2 {
            report.push(
                26,
                label,
                "--speculative",
                format!(
                    "no chunked slab width survives the ladder {:?} (cap {:?}) — nothing \
                     can verify a draft",
                    entry.prefill_chunks, spec.prefill_chunk
                ),
                "raise --prefill-chunk or export slab programs",
            );
        }
        for &w in widths.iter().filter(|&&w| w > 1) {
            if !entry.verify_widths.contains(&w) {
                report.push(
                    26,
                    label,
                    "--speculative",
                    format!(
                        "width {w} is not in verify_widths {:?} — its slab program is \
                         last-position only",
                        entry.verify_widths
                    ),
                    "re-export the artifacts to get all-position logits",
                );
            }
        }
        if spec.temperature > 0.0 {
            report.push(
                27,
                label,
                "--temperature",
                format!(
                    "speculation verifies greedy prefixes; temperature {} breaks the \
                     accept rule",
                    spec.temperature
                ),
                "drop --temperature or --speculative",
            );
        }
        if *draft_rank == 0 || *draft_rank >= d_head {
            report.push(
                24,
                label,
                "--draft-rank",
                format!("draft rank {draft_rank} must be in 1..{d_head} to be a cheaper proposer"),
                "pick a rank strictly below the dense head dim",
            );
        }
    }

    // -- per-step token budget vs the ladder ------------------------------
    if let Some(budget) = spec.max_step_tokens {
        if let Some(&wmin) = widths.iter().find(|&&w| w > 1) {
            if budget < wmin {
                report.push(
                    28,
                    label,
                    "--max-step-tokens",
                    format!(
                        "budget {budget} is below the smallest slab width {wmin} — every \
                         prefill falls back to width 1"
                    ),
                    "raise the budget to at least the smallest chunk width",
                );
            }
        }
    }

    // -- radix prefix cache: block alignment, pair legality, eviction -----
    if let Some(block) = spec.prefix_cache_block {
        // Cached blocks map to whole KV pages *and* whole skipped prefill
        // steps, so the block must be a positive page multiple that some
        // chunked ladder rung tiles exactly (a ladder capped to width 1
        // has no rung to align to and any page multiple passes).
        let ladder_ok = widths.iter().all(|&w| w <= 1)
            || widths.iter().any(|&w| w > 1 && block % w == 0);
        if block == 0 || block % PAGE_TOKENS != 0 || !ladder_ok {
            report.push(
                34,
                label,
                "--prefix-cache-block",
                format!(
                    "block {block} must be a positive multiple of {PAGE_TOKENS} that a chunk \
                     width from the ladder {widths:?} tiles exactly — cached blocks map to \
                     whole pages and whole skipped prefill steps"
                ),
                "use a page-multiple ladder width (e.g. 32)",
            );
        }
        if spec.speculative.is_some() {
            report.push(
                35,
                label,
                "--prefix-cache-block",
                "a draft+verify pair rewrites speculative lane positions the prefix cache \
                 may share copy-on-write — the engine refuses the combination at spawn"
                    .to_string(),
                "drop --speculative or --prefix-cache-block",
            );
        }
        match spec.kv_memory_budget {
            None => report.push(
                36,
                label,
                "--kv-memory-budget",
                format!(
                    "prefix cache (block {block}) without --kv-memory-budget never feels \
                     memory pressure — cached pages accumulate without ever evicting"
                ),
                "set --kv-memory-budget so LRU-by-attention-mass eviction has a bound",
            ),
            Some(budget) => {
                if stored.is_some() && block > 0 {
                    let cache_cfg = KvConfig {
                        n_layers,
                        n_heads,
                        rank,
                        max_positions: seq_len,
                        batch_slots: spec.batch_slots,
                        codec: spec.kv_codec.clone(),
                    };
                    let block_bytes = cache_cfg.bytes_per_page() * block.div_ceil(PAGE_TOKENS);
                    if budget < block_bytes {
                        report.push(
                            36,
                            label,
                            "--kv-memory-budget",
                            format!(
                                "budget {budget} B cannot retain one cached block \
                                 ({block_bytes} B at block {block}) — every donated prefix \
                                 is evicted before it can ever be hit"
                            ),
                            "raise the budget or shrink --prefix-cache-block",
                        );
                    }
                }
            }
        }
    }

    // -- KV memory budget vs worst-case page reservations -----------------
    if stored.is_none() {
        return; // codec already failed to resolve; no byte math to do
    }
    let Some(budget) = spec.kv_memory_budget else { return };
    let target = KvConfig {
        n_layers,
        n_heads,
        rank,
        max_positions: seq_len,
        batch_slots: spec.batch_slots,
        codec: spec.kv_codec.clone(),
    };
    let draft_page = match &spec.speculative {
        Some((dr, _)) if *dr >= 1 && *dr < d_head => KvConfig {
            n_layers,
            n_heads,
            rank: *dr,
            max_positions: seq_len,
            batch_slots: spec.batch_slots,
            codec: KvCodecSpec::Identity,
        }
        .bytes_per_page(),
        _ => 0,
    };
    // Resident bytes per page: the target's codec-compressed pages plus,
    // for a draft+verify pair, the draft's identity pages — the same sum
    // the engine's budget admission reserves against.
    let resident = target.bytes_per_page() + draft_page;
    if budget < resident {
        report.push(
            29,
            label,
            "--kv-memory-budget",
            format!(
                "budget {budget} B is below one resident page ({resident} B) — admission \
                 can never pass"
            ),
            "raise the budget or compress harder (--kv-codec factored)",
        );
    } else {
        let worst = seq_len.div_ceil(PAGE_TOKENS) * resident;
        if budget < worst {
            report.push(
                30,
                label,
                "--kv-memory-budget",
                format!(
                    "budget {budget} B is below one full-window request ({worst} B) — a \
                     max-length request can never be admitted"
                ),
                "acceptable if requests stay short; raise the budget otherwise",
            );
        }
    }
}

/// Check one committed run config (`*.toml`): parse, validate, and
/// cross-reference against the manifest when one was loaded.
pub fn check_run_config(report: &mut Report, path: &str, manifest: Option<&Manifest>) {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            report.push(31, path, "$", format!("cannot read: {e}"), "");
            return;
        }
    };
    // `from_toml_str` validates internally, so classify its failures: a
    // document that is not TOML at all is CLV031; one that parses but
    // breaks a validation bound is CLV032.
    let cfg = match RunConfig::from_toml_str(&text) {
        Ok(c) => c,
        Err(e) => {
            if crate::config::toml::parse(&text).is_ok() {
                report.push(32, path, "$", format!("{e:#}"), "see config/mod.rs for the bounds");
            } else {
                report.push(31, path, "$", format!("parse failed: {e:#}"), "");
            }
            return;
        }
    };
    let Some(m) = manifest else { return };
    let Ok(entry) = m.config(&cfg.model.preset) else {
        report.push(
            33,
            path,
            "model.preset",
            format!(
                "preset {:?} is not in the checked manifest (have: {:?})",
                cfg.model.preset,
                m.configs.keys().collect::<Vec<_>>()
            ),
            "export the preset or fix the name",
        );
        return;
    };
    if !entry.ranks.contains(&cfg.serve.kv_rank) {
        report.push(
            33,
            path,
            "serve.kv_rank",
            format!(
                "kv_rank {} is not an exported rank (ladder {:?})",
                cfg.serve.kv_rank, entry.ranks
            ),
            "pick a rank from the manifest's ladder",
        );
    }
}
