//! Bench-document checks (`CLV040`–`CLV045`) against the shapes in
//! `docs/BENCH_SCHEMAS.md`.
//!
//! Documents are dispatched the same way `scripts/check_bench.py` does:
//! a `bench` id selects the serve/server schema, a `traceEvents` array is
//! a Chrome trace-event dump, a `counters`+`gauges` pair is a metrics
//! registry dump; anything else is `CLV041`.
//!
//! Two tiers of requirements keep the committed `BENCH_history/`
//! bootstrap snapshots checkable:
//!
//! * **hard** keys (`CLV042` error) — the row-identity structure every
//!   document must carry (`bench`, `preset`, the section tables and the
//!   keys that identify a row: `chunk`, `draft_len`, `codec`,
//!   `budgets`);
//! * **soft** keys (`CLV045` warning) — measured values that a bootstrap
//!   snapshot legitimately carries as `null` until a real run is
//!   committed (see `BENCH_history/README.md`).
//!
//! Invariants (`CLV044`) are enforced only on non-null values: the
//! speculative and prefix-cache bit-identity bits, budgets within
//! `1..=rank`, prefix
//! agreement a fraction (and exactly 1.0 for a full-rank profile),
//! `open_spans == 0`, span-reconstruction agreement, time-ordered step
//! lanes.  The *performance bars* (>=4x prefill-step reduction, <1.0
//! dense steps/token, >=2x lanes, <5% tap overhead) stay in
//! `check_bench.py` — they gate fresh measurements in CI, not committed
//! documents.

use crate::config::json::Json;

use super::diag::Report;

/// Check one parsed bench document.
pub fn check_bench_doc(report: &mut Report, path: &str, doc: &Json) {
    walk_non_finite(report, path, doc, "$");
    match doc.get("bench").and_then(|b| b.as_str().ok()) {
        Some("perf_serve") => check_serve(report, path, doc),
        Some("perf_server") => check_server(report, path, doc),
        Some(other) => {
            report.push(
                41,
                path,
                "$.bench",
                format!("unknown bench id {other:?}"),
                "see docs/BENCH_SCHEMAS.md for the known documents",
            );
        }
        None if doc.get("traceEvents").is_some() => check_trace(report, path, doc),
        None if doc.get("counters").is_some() && doc.get("gauges").is_some() => {
            check_metrics(report, path, doc);
        }
        None => {
            report.push(
                41,
                path,
                "$",
                "no `bench` id, `traceEvents`, or `counters`+`gauges` — unrecognized shape"
                    .to_string(),
                "see docs/BENCH_SCHEMAS.md for the known documents",
            );
        }
    }
}

/// Read a file and check it (`CLV040` on IO/parse failure).
pub fn check_bench_file(report: &mut Report, path: &str) {
    let text = match std::fs::read_to_string(path) {
        Ok(t) => t,
        Err(e) => {
            report.push(40, path, "$", format!("cannot read: {e}"), "");
            return;
        }
    };
    match Json::parse(&text) {
        Ok(doc) => check_bench_doc(report, path, &doc),
        Err(e) => report.push(40, path, "$", format!("not valid JSON: {e}"), ""),
    }
}

fn num(v: &Json) -> Option<f64> {
    match v {
        Json::Num(x) => Some(*x),
        _ => None,
    }
}

/// `CLV043` for every non-finite number anywhere in the document (the
/// parser lets `1e999` through as `inf`; `json.dump` would have written
/// `Infinity`, which python's reader happily round-trips).
fn walk_non_finite(report: &mut Report, path: &str, v: &Json, locus: &str) {
    match v {
        Json::Num(x) if !x.is_finite() => {
            report.push(
                43,
                path,
                locus,
                format!("non-finite number {x}"),
                "a NaN/inf here means the bench harness divided by zero",
            );
        }
        Json::Arr(items) => {
            for (i, item) in items.iter().enumerate() {
                walk_non_finite(report, path, item, &format!("{locus}[{i}]"));
            }
        }
        Json::Obj(m) => {
            for (k, item) in m {
                walk_non_finite(report, path, item, &format!("{locus}.{k}"));
            }
        }
        _ => {}
    }
}

/// Hard requirement: missing key is a structural error.
fn require(report: &mut Report, path: &str, v: &Json, locus: &str, keys: &[&str]) {
    for k in keys {
        if v.get(k).is_none() {
            report.push(
                42,
                path,
                &format!("{locus}.{k}"),
                format!("missing required key {k:?}"),
                "see docs/BENCH_SCHEMAS.md",
            );
        }
    }
}

/// Soft requirement: absent *or* null is a bootstrap placeholder.
fn soft(report: &mut Report, path: &str, v: &Json, locus: &str, keys: &[&str]) {
    for k in keys {
        if matches!(v.get(k), None | Some(Json::Null)) {
            report.push(
                45,
                path,
                &format!("{locus}.{k}"),
                format!("{k} is absent or null — bootstrap placeholder, not a measurement"),
                "commit a real run over the snapshot (BENCH_history/README.md)",
            );
        }
    }
}

fn check_serve(report: &mut Report, path: &str, doc: &Json) {
    require(report, path, doc, "$", &["preset", "prefill", "speculative", "kv_codec"]);
    require(report, path, doc, "$", &["layer_budgets"]);
    soft(report, path, doc, "$", &["obs", "engines", "pjrt_skipped"]);
    soft(report, path, doc, "$", &["prefix_cache", "fault_recovery"]);

    if let Some(prefill) = doc.get("prefill") {
        require(report, path, prefill, "$.prefill", &["chunks"]);
        let chunks = prefill.get("chunks").and_then(|c| c.as_arr().ok()).unwrap_or(&[]);
        if chunks.is_empty() {
            report.push(
                44,
                path,
                "$.prefill.chunks",
                "empty — the chunk ladder was not benched".to_string(),
                "run `cargo bench --bench perf_serve`",
            );
        }
        for (i, row) in chunks.iter().enumerate() {
            require(report, path, row, &format!("$.prefill.chunks[{i}]"), &["chunk"]);
        }
    }

    if let Some(spec) = doc.get("speculative") {
        require(report, path, spec, "$.speculative", &["sweep"]);
        let sweep = spec.get("sweep").and_then(|s| s.as_arr().ok()).unwrap_or(&[]);
        for (i, row) in sweep.iter().enumerate() {
            let locus = format!("$.speculative.sweep[{i}]");
            require(report, path, row, &locus, &["draft_len"]);
            match row.get("bit_identical_to_vanilla") {
                Some(Json::Bool(true)) => {}
                Some(Json::Bool(false)) => {
                    report.push(
                        44,
                        path,
                        &locus,
                        "speculative greedy output diverged from vanilla greedy decode — \
                         the bit-identity invariant is broken"
                            .to_string(),
                        "a lossy accept rule or draft-cache leak; bisect the engine",
                    );
                }
                _ => soft(report, path, row, &locus, &["bit_identical_to_vanilla"]),
            }
        }
    }

    if let Some(kvc) = doc.get("kv_codec") {
        require(report, path, kvc, "$.kv_codec", &["codecs"]);
        let codecs = kvc.get("codecs").and_then(|c| c.as_arr().ok()).unwrap_or(&[]);
        let mut has_identity = false;
        for (i, row) in codecs.iter().enumerate() {
            let locus = format!("$.kv_codec.codecs[{i}]");
            require(report, path, row, &locus, &["codec", "layer_budgets"]);
            if row.get("codec").and_then(|c| c.as_str().ok()) == Some("identity") {
                has_identity = true;
            }
        }
        if !codecs.is_empty() && !has_identity {
            report.push(
                44,
                path,
                "$.kv_codec.codecs",
                "no identity row to compare the compressed codecs against".to_string(),
                "the sweep must include the identity baseline",
            );
        }
    }

    if let Some(lb) = doc.get("layer_budgets") {
        require(report, path, lb, "$.layer_budgets", &["rank", "profiles"]);
        let rank = lb.get("rank").and_then(num).unwrap_or(0.0) as usize;
        let profiles = lb.get("profiles").and_then(|p| p.as_arr().ok()).unwrap_or(&[]);
        for (i, row) in profiles.iter().enumerate() {
            let locus = format!("$.layer_budgets.profiles[{i}]");
            require(report, path, row, &locus, &["budgets"]);
            let budgets = row.get("budgets").and_then(|b| b.as_shape().ok()).unwrap_or_default();
            for &b in &budgets {
                if rank > 0 && (b == 0 || b > rank) {
                    report.push(
                        44,
                        path,
                        &locus,
                        format!("budget {b} outside 1..={rank}"),
                        "budgets are per-layer stored ranks",
                    );
                }
            }
            match row.get("mean_prefix_agreement") {
                Some(Json::Num(a)) if !(0.0..=1.0).contains(a) => {
                    report.push(
                        44,
                        path,
                        &locus,
                        format!("mean_prefix_agreement {a} is not a fraction in [0, 1]"),
                        "",
                    );
                }
                Some(Json::Num(a)) => {
                    let full = !budgets.is_empty() && budgets.iter().all(|&b| b == rank);
                    if full && *a != 1.0 {
                        report.push(
                            44,
                            path,
                            &locus,
                            format!(
                                "full-rank budgets must agree exactly with the identity \
                                 trace (got {a})"
                            ),
                            "full budgets make the factored codec a pure copy",
                        );
                    }
                }
                _ => soft(report, path, row, &locus, &["mean_prefix_agreement"]),
            }
        }
    }

    if let Some(pc) = doc.get("prefix_cache") {
        if !matches!(pc, Json::Null) {
            require(report, path, pc, "$.prefix_cache", &["sweep"]);
            let sweep = pc.get("sweep").and_then(|s| s.as_arr().ok()).unwrap_or(&[]);
            let rows: Vec<(String, &Json)> = sweep
                .iter()
                .enumerate()
                .map(|(i, row)| (format!("$.prefix_cache.sweep[{i}]"), row))
                .chain(pc.get("tight_budget").map(|t| ("$.prefix_cache.tight_budget".to_string(), t)))
                .collect();
            for (locus, row) in rows {
                require(report, path, row, &locus, &["share"]);
                match row.get("bit_identical_to_cold") {
                    Some(Json::Bool(true)) => {}
                    Some(Json::Bool(false)) => {
                        report.push(
                            44,
                            path,
                            &locus,
                            "cached serve diverged from the cold prefill trace — the \
                             bit-identity invariant is broken"
                                .to_string(),
                            "a COW aliasing or stale-attach bug; bisect the prefix cache",
                        );
                    }
                    _ => soft(report, path, row, &locus, &["bit_identical_to_cold"]),
                }
            }
        }
    }

    if let Some(fr) = doc.get("fault_recovery") {
        if !matches!(fr, Json::Null) {
            require(report, path, fr, "$.fault_recovery", &["rates", "recovery", "failover"]);
            let rates = fr.get("rates").and_then(|r| r.as_arr().ok()).unwrap_or(&[]);
            // The sweep rows carry `bit_identical_to_fault_free`, the
            // two drills carry `bit_identical` — same invariant.
            let rows: Vec<(String, &Json, &str)> = rates
                .iter()
                .enumerate()
                .map(|(i, row)| {
                    (format!("$.fault_recovery.rates[{i}]"), row, "bit_identical_to_fault_free")
                })
                .chain(fr.get("recovery").map(|r| {
                    ("$.fault_recovery.recovery".to_string(), r, "bit_identical")
                }))
                .chain(fr.get("failover").map(|r| {
                    ("$.fault_recovery.failover".to_string(), r, "bit_identical")
                }))
                .collect();
            for (locus, row, bit_key) in rows {
                // The conservation bar: no fault plan may lose a request.
                match row.get("lost") {
                    Some(Json::Num(n)) if *n != 0.0 => {
                        report.push(
                            44,
                            path,
                            &locus,
                            format!("lost {n} != 0 — a request vanished without a terminal event"),
                            "the conservation ledger must balance under every fault plan",
                        );
                    }
                    Some(Json::Num(_)) => {}
                    _ => soft(report, path, row, &locus, &["lost"]),
                }
                match row.get(bit_key) {
                    Some(Json::Bool(true)) => {}
                    Some(Json::Bool(false)) => {
                        report.push(
                            44,
                            path,
                            &locus,
                            "recovered rows diverged from the fault-free serve — the \
                             bit-identity invariant is broken"
                                .to_string(),
                            "replay must resume from prompt \u{29fa} streamed; bisect the replay book",
                        );
                    }
                    _ => soft(report, path, row, &locus, &[bit_key]),
                }
            }
        }
    }

    if let Some(obs) = doc.get("obs") {
        soft(report, path, obs, "$.obs", &["tap_overhead_frac", "recon", "metrics"]);
        match obs.get("open_spans") {
            Some(Json::Num(n)) if *n != 0.0 => {
                report.push(
                    44,
                    path,
                    "$.obs.open_spans",
                    format!("{n} request span(s) never saw a terminal event"),
                    "every span must close with Done or Cancelled",
                );
            }
            _ => {}
        }
        if let (Some(recon), Some(metrics)) = (obs.get("recon"), obs.get("metrics")) {
            for key in ["completed", "cancelled", "generated_tokens"] {
                let (r, m) = (recon.get(key).and_then(num), metrics.get(key).and_then(num));
                if let (Some(r), Some(m)) = (r, m) {
                    if r != m {
                        report.push(
                            44,
                            path,
                            &format!("$.obs.recon.{key}"),
                            format!("recon {r} != metrics {m} — the span timelines lost events"),
                            "",
                        );
                    }
                }
            }
        }
    }
}

fn check_server(report: &mut Report, path: &str, doc: &Json) {
    require(report, path, doc, "$", &["preset", "stub_streaming", "skipped"]);
    if let Some(ss) = doc.get("stub_streaming") {
        require(
            report,
            path,
            ss,
            "$.stub_streaming",
            &["requests", "prompt_tokens", "completed", "mean_prefill_steps", "decode_steps"],
        );
    }
}

fn check_trace(report: &mut Report, path: &str, doc: &Json) {
    require(report, path, doc, "$", &["traceEvents", "displayTimeUnit"]);
    let events = doc.get("traceEvents").and_then(|e| e.as_arr().ok()).unwrap_or(&[]);
    let mut last_step_ts = f64::NEG_INFINITY;
    let mut step_order_ok = true;
    for (i, ev) in events.iter().enumerate() {
        let locus = format!("$.traceEvents[{i}]");
        require(report, path, ev, &locus, &["name", "ph", "pid", "tid", "ts"]);
        let ts = ev.get("ts").and_then(num);
        if let Some(ts) = ts {
            if ts < 0.0 {
                report.push(44, path, &locus, format!("ts {ts} is negative"), "");
            }
        }
        if ev.get("ph").and_then(|p| p.as_str().ok()) == Some("X") {
            match ev.get("dur").and_then(num) {
                Some(d) if d < 0.0 => {
                    report.push(44, path, &locus, format!("dur {d} is negative"), "");
                }
                Some(_) => {}
                None => {
                    report.push(
                        42,
                        path,
                        &format!("{locus}.dur"),
                        "complete (\"X\") event without a dur".to_string(),
                        "see docs/BENCH_SCHEMAS.md",
                    );
                }
            }
            if ev.get("pid").and_then(num) == Some(0.0) {
                if let Some(ts) = ts {
                    if ts < last_step_ts && step_order_ok {
                        step_order_ok = false;
                        report.push(
                            44,
                            path,
                            &locus,
                            format!(
                                "step lane timestamps regress ({ts} after {last_step_ts}) — \
                                 the step ring is not time-ordered"
                            ),
                            "",
                        );
                    }
                    last_step_ts = last_step_ts.max(ts);
                }
            }
        }
    }
}

fn check_metrics(report: &mut Report, path: &str, doc: &Json) {
    for section in ["counters", "gauges"] {
        let Some(obj) = doc.get(section).and_then(|s| s.as_obj().ok()) else {
            report.push(
                42,
                path,
                &format!("$.{section}"),
                format!("{section} is not an object of series"),
                "see docs/BENCH_SCHEMAS.md",
            );
            continue;
        };
        for (series, v) in obj {
            let locus = format!("$.{section}.{series}");
            match num(v) {
                Some(x) if section == "counters" && x < 0.0 => {
                    report.push(44, path, &locus, format!("counter is negative ({x})"), "");
                }
                Some(_) => {}
                None => {
                    report.push(42, path, &locus, "series value is not a number".to_string(), "");
                }
            }
        }
    }
}
