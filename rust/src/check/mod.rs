//! `clover check` — static diagnostics over the deployable surface.
//!
//! Everything here runs without executing a single XLA program: it
//! cross-validates the *documents* a deployment is assembled from —
//! exported manifests ([`manifest`]), engine flag combinations and
//! committed run configs ([`serve`]), and committed bench documents
//! ([`bench`]) — and reports problems as structured [`Diagnostic`]s
//! with stable `CLV0xx` codes, a path + locus, and a fix hint.
//!
//! The catalog of codes lives in [`diag::CATALOG`] and is documented
//! (test-enforced) in `docs/STATIC_ANALYSIS.md`.  The CLI verb
//! (`clover check`) renders a [`Report`] as text or JSON and exits
//! non-zero when any error-severity diagnostic fired, which is what
//! lets CI gate merges on it.

pub mod bench;
pub mod diag;
pub mod manifest;
pub mod serve;

pub use bench::{check_bench_doc, check_bench_file};
pub use diag::{catalog_entry, CatalogEntry, Diagnostic, Report, Severity, CATALOG};
pub use manifest::{check_manifest_dir, ManifestCheckOpts};
pub use serve::{check_engine_spec, check_run_config, ServeSpec};
