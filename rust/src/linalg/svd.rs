//! Singular value decomposition via one-sided Jacobi rotations.
//!
//! `svd(A)` for A [m×n] returns U [m×k], σ [k] (descending), Vᵀ [k×n] with
//! k = min(m, n).  One-sided Jacobi operates on the columns of A (m ≥ n;
//! the wide case is handled by transposing), accumulating V; it is simple,
//! unconditionally stable, and exactly what the CLOVER transform needs for
//! the small d×d cross-layer cores (and the D×D analysis matrices of
//! Figs 5–6; at D ≤ 768 a few Jacobi sweeps are sub-second in release).
//!
//! f64 accumulation is used for the column inner products — the rotation
//! angles are the numerically delicate part at f32.

use crate::tensor::Tensor;

/// SVD result: `a ≈ u · diag(s) · vt`.
pub struct Svd {
    pub u: Tensor,
    pub s: Vec<f32>,
    pub vt: Tensor,
}

const MAX_SWEEPS: usize = 60;
const TOL: f64 = 1e-10;

/// One-sided Jacobi SVD (see module docs).
pub fn svd(a: &Tensor) -> Svd {
    assert_eq!(a.ndim(), 2);
    let (m, n) = (a.shape()[0], a.shape()[1]);
    if m < n {
        // SVD(Aᵀ) = V Σ Uᵀ.
        let t = svd(&a.transpose2());
        return Svd { u: t.vt.transpose2(), s: t.s, vt: t.u.transpose2() };
    }

    // Column-major working copy: cols[j][i] = A[i][j].
    let mut cols: Vec<Vec<f64>> = (0..n)
        .map(|j| (0..m).map(|i| a.at2(i, j) as f64).collect())
        .collect();
    // V accumulated as columns.
    let mut v: Vec<Vec<f64>> = (0..n)
        .map(|j| {
            let mut e = vec![0.0f64; n];
            e[j] = 1.0;
            e
        })
        .collect();

    for _sweep in 0..MAX_SWEEPS {
        let mut off = 0.0f64;
        for p in 0..n {
            for q in (p + 1)..n {
                let mut app = 0.0f64;
                let mut aqq = 0.0f64;
                let mut apq = 0.0f64;
                for i in 0..m {
                    app += cols[p][i] * cols[p][i];
                    aqq += cols[q][i] * cols[q][i];
                    apq += cols[p][i] * cols[q][i];
                }
                if apq.abs() <= TOL * (app * aqq).sqrt().max(1e-300) {
                    continue;
                }
                off = off.max(apq.abs());
                // Jacobi rotation zeroing the (p,q) entry of AᵀA.
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let xp = cols[p][i];
                    let xq = cols[q][i];
                    cols[p][i] = c * xp - s * xq;
                    cols[q][i] = s * xp + c * xq;
                }
                for i in 0..n {
                    let vp = v[p][i];
                    let vq = v[q][i];
                    v[p][i] = c * vp - s * vq;
                    v[q][i] = s * vp + c * vq;
                }
            }
        }
        if off < TOL {
            break;
        }
    }

    // Extract singular values (column norms), sort descending.
    let mut order: Vec<usize> = (0..n).collect();
    let norms: Vec<f64> = cols.iter()
        .map(|c| c.iter().map(|x| x * x).sum::<f64>().sqrt())
        .collect();
    order.sort_by(|&i, &j| norms[j].partial_cmp(&norms[i]).unwrap());

    let mut u = vec![0.0f32; m * n];
    let mut s = vec![0.0f32; n];
    let mut vt = vec![0.0f32; n * n];
    for (rank, &j) in order.iter().enumerate() {
        let sigma = norms[j];
        s[rank] = sigma as f32;
        if sigma > 1e-30 {
            for i in 0..m {
                u[i * n + rank] = (cols[j][i] / sigma) as f32;
            }
        } else {
            // Null direction: leave U column zero; truncation drops it.
        }
        for i in 0..n {
            vt[rank * n + i] = v[j][i] as f32;
        }
    }

    Svd {
        u: Tensor::new(vec![m, n], u),
        s,
        vt: Tensor::new(vec![n, n], vt),
    }
}

/// Reconstruct `u[:, :r] · diag(s[:r]) · vt[:r, :]`.
pub fn reconstruct(svd: &Svd, r: usize) -> Tensor {
    let m = svd.u.shape()[0];
    let n = svd.vt.shape()[1];
    let r = r.min(svd.s.len());
    let mut out = vec![0.0f32; m * n];
    for k in 0..r {
        let sk = svd.s[k];
        if sk == 0.0 {
            continue;
        }
        for i in 0..m {
            let uik = svd.u.at2(i, k) * sk;
            if uik == 0.0 {
                continue;
            }
            let vrow = &svd.vt.data()[k * n..(k + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += uik * vrow[j];
            }
        }
    }
    Tensor::new(vec![m, n], out)
}

/// Energy retained by the top-r singular values: Σ_{i<r} σᵢ² / Σ σᵢ².
pub fn energy_retained(s: &[f32], r: usize) -> f32 {
    let total: f32 = s.iter().map(|x| x * x).sum();
    if total <= 0.0 {
        return 1.0;
    }
    let kept: f32 = s.iter().take(r).map(|x| x * x).sum();
    kept / total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, matmul_nt, ortho_defect, scale_cols};
    use crate::testing::{prop, rel_err};

    fn random_lowrank(rng: &mut crate::util::rng::Rng, m: usize, n: usize, r: usize) -> Tensor {
        let a = Tensor::new(vec![m, r], rng.normal_vec(m * r, 1.0));
        let b = Tensor::new(vec![n, r], rng.normal_vec(n * r, 1.0));
        matmul_nt(&a, &b)
    }

    #[test]
    fn reconstruction_property() {
        prop("SVD: ‖A − U·S·Vᵀ‖/‖A‖ ≤ 1e-4", 25, |rng| {
            let m = rng.range(1, 16);
            let n = rng.range(1, 16);
            let a = Tensor::new(vec![m, n], rng.normal_vec(m * n, 1.0));
            let d = svd(&a);
            let back = reconstruct(&d, m.min(n));
            let err = rel_err(back.data(), a.data());
            if err > 1e-4 {
                return Err(format!("rel err {err} for {m}x{n}"));
            }
            Ok(())
        });
    }

    #[test]
    fn orthogonality_property() {
        prop("SVD: U, V orthonormal", 20, |rng| {
            let m = rng.range(2, 12);
            let n = rng.range(2, 12);
            let a = Tensor::new(vec![m, n], rng.normal_vec(m * n, 1.0));
            let d = svd(&a);
            // Only the non-null columns of U are orthonormal; with
            // m >= n and a generic random matrix all are.
            if m >= n {
                let du = ortho_defect(&d.u);
                if du > 1e-4 {
                    return Err(format!("U defect {du}"));
                }
            }
            let dv = ortho_defect(&d.vt.transpose2());
            if dv > 1e-4 {
                return Err(format!("V defect {dv}"));
            }
            Ok(())
        });
    }

    #[test]
    fn singular_values_sorted_nonneg() {
        prop("SVD: σ descending, ≥ 0", 20, |rng| {
            let m = rng.range(1, 14);
            let n = rng.range(1, 14);
            let a = Tensor::new(vec![m, n], rng.normal_vec(m * n, 2.0));
            let d = svd(&a);
            for w in d.s.windows(2) {
                if w[1] > w[0] + 1e-6 {
                    return Err(format!("not sorted: {:?}", d.s));
                }
            }
            if d.s.iter().any(|&x| x < 0.0) {
                return Err("negative sigma".into());
            }
            Ok(())
        });
    }

    #[test]
    fn exact_rank_detection() {
        prop("SVD: rank-r matrix has n-r zero sigmas", 15, |rng| {
            let n = rng.range(4, 10);
            let r = rng.range(1, n.min(4));
            let a = random_lowrank(rng, n + 3, n, r);
            let d = svd(&a);
            let tail: f32 = d.s[r..].iter().sum();
            let head = d.s[0];
            if tail > 1e-3 * head.max(1.0) {
                return Err(format!("rank {r}: tail {tail}, s = {:?}", d.s));
            }
            // And truncated reconstruction at r is exact.
            let back = reconstruct(&d, r);
            let err = rel_err(back.data(), a.data());
            if err > 1e-4 {
                return Err(format!("truncated rel err {err}"));
            }
            Ok(())
        });
    }

    #[test]
    fn known_diagonal() {
        let a = Tensor::new(vec![2, 2], vec![3.0, 0.0, 0.0, -2.0]);
        let d = svd(&a);
        assert!((d.s[0] - 3.0).abs() < 1e-5);
        assert!((d.s[1] - 2.0).abs() < 1e-5);
    }

    #[test]
    fn wide_matrix() {
        let mut rng = crate::util::rng::Rng::new(9);
        let a = Tensor::new(vec![3, 8], rng.normal_vec(24, 1.0));
        let d = svd(&a);
        assert_eq!(d.u.shape(), &[3, 3]);
        assert_eq!(d.vt.shape(), &[3, 8]);
        let back = reconstruct(&d, 3);
        assert!(rel_err(back.data(), a.data()) < 1e-4);
    }

    #[test]
    fn energy_retained_bounds() {
        let s = vec![2.0, 1.0, 0.0];
        assert!((energy_retained(&s, 3) - 1.0).abs() < 1e-6);
        assert!((energy_retained(&s, 1) - 0.8).abs() < 1e-6);
        assert_eq!(energy_retained(&[], 0), 1.0);
    }

    #[test]
    fn u_s_vt_agrees_with_scale_cols() {
        // u·diag(s)·vt == reconstruct for full rank
        let mut rng = crate::util::rng::Rng::new(3);
        let a = Tensor::new(vec![5, 4], rng.normal_vec(20, 1.0));
        let d = svd(&a);
        let usv = matmul(&scale_cols(&d.u, &d.s), &d.vt);
        assert!(rel_err(usv.data(), a.data()) < 1e-4);
    }
}
