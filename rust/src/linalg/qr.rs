//! Thin QR factorization via Modified Gram–Schmidt with reorthogonalization.
//!
//! A [m×n] with m ≥ n  →  Q [m×n] (orthonormal columns), R [n×n] (upper
//! triangular), A = Q·R.  MGS-with-a-second-pass ("twice is enough",
//! Giraud et al.) gives orthogonality defect at f32 roundoff for the
//! well-scaled matrices the CLOVER transform feeds it; rank-deficient
//! columns are replaced by a deterministic fallback direction and get a
//! zero R row, which the downstream SVD truncation then discards.

use crate::tensor::Tensor;

/// Result of [`qr_thin`].
pub struct Qr {
    pub q: Tensor,
    pub r: Tensor,
}

/// Thin (reduced) QR of a tall matrix.
pub fn qr_thin(a: &Tensor) -> Qr {
    assert_eq!(a.ndim(), 2);
    let (m, n) = (a.shape()[0], a.shape()[1]);
    assert!(m >= n, "qr_thin needs m >= n, got {m}x{n}");
    // Column-major working copy of Q for contiguous column ops.
    let mut qcols: Vec<Vec<f32>> = (0..n)
        .map(|j| (0..m).map(|i| a.at2(i, j)).collect())
        .collect();
    let mut r = vec![0.0f32; n * n];

    let eps = 1e-12f32;
    for j in 0..n {
        // Two MGS passes against previous columns.
        for _pass in 0..2 {
            for i in 0..j {
                let proj: f32 = qcols[i].iter().zip(qcols[j].iter()).map(|(a, b)| a * b).sum();
                r[i * n + j] += proj;
                let qi = qcols[i].clone();
                for (x, qv) in qcols[j].iter_mut().zip(qi.iter()) {
                    *x -= proj * qv;
                }
            }
        }
        let norm: f32 = qcols[j].iter().map(|x| x * x).sum::<f32>().sqrt();
        if norm > eps {
            r[j * n + j] = norm;
            for x in qcols[j].iter_mut() {
                *x /= norm;
            }
        } else {
            // Rank-deficient column: R row stays ~0; substitute a unit
            // vector orthogonalized against previous columns so Q still has
            // orthonormal columns.
            r[j * n + j] = 0.0;
            let mut best = vec![0.0f32; m];
            'outer: for basis in 0..m {
                let mut cand = vec![0.0f32; m];
                cand[basis] = 1.0;
                for qi in qcols.iter().take(j) {
                    let proj: f32 = qi.iter().zip(cand.iter()).map(|(a, b)| a * b).sum();
                    for (c, qv) in cand.iter_mut().zip(qi.iter()) {
                        *c -= proj * qv;
                    }
                }
                let nn: f32 = cand.iter().map(|x| x * x).sum::<f32>().sqrt();
                if nn > 0.5 {
                    for c in cand.iter_mut() {
                        *c /= nn;
                    }
                    best = cand;
                    break 'outer;
                }
            }
            qcols[j] = best;
        }
    }

    let mut qdata = vec![0.0f32; m * n];
    for (j, col) in qcols.iter().enumerate() {
        for i in 0..m {
            qdata[i * n + j] = col[i];
        }
    }
    Qr { q: Tensor::new(vec![m, n], qdata), r: Tensor::new(vec![n, n], r) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{matmul, ortho_defect};
    use crate::testing::{assert_close, prop};

    #[test]
    fn reconstructs_and_orthonormal() {
        prop("QR: A == Q·R, QᵀQ == I", 30, |rng| {
            let n = rng.range(1, 12);
            let m = n + rng.range(0, 20);
            let a = Tensor::new(vec![m, n], rng.normal_vec(m * n, 1.0));
            let Qr { q, r } = qr_thin(&a);
            let back = matmul(&q, &r);
            assert_close(back.data(), a.data(), 1e-4, 1e-3)?;
            let defect = ortho_defect(&q);
            if defect > 1e-4 {
                return Err(format!("ortho defect {defect}"));
            }
            Ok(())
        });
    }

    #[test]
    fn r_is_upper_triangular() {
        prop("QR: R upper triangular", 20, |rng| {
            let n = rng.range(2, 10);
            let m = n + rng.range(0, 5);
            let a = Tensor::new(vec![m, n], rng.normal_vec(m * n, 1.0));
            let Qr { r, .. } = qr_thin(&a);
            for i in 1..n {
                for j in 0..i {
                    if r.at2(i, j).abs() > 1e-5 {
                        return Err(format!("R[{i},{j}] = {}", r.at2(i, j)));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn handles_rank_deficiency() {
        // Two identical columns: still orthonormal Q, A == Q·R.
        let a = Tensor::new(vec![4, 2], vec![1., 1., 2., 2., 3., 3., 4., 4.]);
        let Qr { q, r } = qr_thin(&a);
        assert!(ortho_defect(&q) < 1e-4);
        let back = matmul(&q, &r);
        assert_close(back.data(), a.data(), 1e-4, 1e-3).unwrap();
    }

    #[test]
    fn zero_matrix() {
        let a = Tensor::zeros(&[5, 3]);
        let Qr { q, r } = qr_thin(&a);
        assert!(ortho_defect(&q) < 1e-4);
        assert!(r.norm() < 1e-6);
    }
}
