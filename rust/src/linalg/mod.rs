//! Dense linear algebra substrate (no BLAS/LAPACK dependency).
//!
//! CLOVER's checkpoint-time transform needs exactly three primitives:
//! matrix multiplication, a thin QR (to reduce the D×d cross-layer factors),
//! and an SVD of small square matrices (one-sided Jacobi).  The analysis
//! passes (Fig 5/6) additionally SVD full D×D update matrices — still fine
//! for Jacobi at D ≤ 768.
//!
//! Everything is f32 in row-major order, matching [`crate::tensor::Tensor`].

pub mod qr;
pub mod svd;

use crate::tensor::Tensor;

/// C = A·B for 2-D tensors, blocked i-k-j loop (cache-friendly row-major).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2);
    assert_eq!(b.ndim(), 2);
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul inner dim: {k} vs {k2}");
    let mut out = vec![0.0f32; m * n];
    let ad = a.data();
    let bd = b.data();
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &aval) in arow.iter().enumerate() {
            if aval == 0.0 {
                continue;
            }
            let brow = &bd[kk * n..(kk + 1) * n];
            for j in 0..n {
                orow[j] += aval * brow[j];
            }
        }
    }
    Tensor::new(vec![m, n], out)
}

/// A·Bᵀ without materializing the transpose.
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2);
    assert_eq!(b.ndim(), 2);
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (n, k2) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul_nt inner dim: {k} vs {k2}");
    let mut out = vec![0.0f32; m * n];
    let ad = a.data();
    let bd = b.data();
    for i in 0..m {
        let arow = &ad[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &bd[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += arow[kk] * brow[kk];
            }
            out[i * n + j] = acc;
        }
    }
    Tensor::new(vec![m, n], out)
}

/// Aᵀ·B without materializing the transpose.
pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.ndim(), 2);
    assert_eq!(b.ndim(), 2);
    let (k, m) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul_tn inner dim: {k} vs {k2}");
    let mut out = vec![0.0f32; m * n];
    let ad = a.data();
    let bd = b.data();
    for kk in 0..k {
        let arow = &ad[kk * m..(kk + 1) * m];
        let brow = &bd[kk * n..(kk + 1) * n];
        for i in 0..m {
            let aval = arow[i];
            if aval == 0.0 {
                continue;
            }
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += aval * brow[j];
            }
        }
    }
    Tensor::new(vec![m, n], out)
}

/// y = A·x (matrix-vector).
pub fn matvec(a: &Tensor, x: &[f32]) -> Vec<f32> {
    assert_eq!(a.ndim(), 2);
    let (m, k) = (a.shape()[0], a.shape()[1]);
    assert_eq!(k, x.len());
    let ad = a.data();
    (0..m)
        .map(|i| {
            let row = &ad[i * k..(i + 1) * k];
            row.iter().zip(x).map(|(a, b)| a * b).sum()
        })
        .collect()
}

/// Multiply a matrix by a diagonal on the right: A·diag(d).
pub fn scale_cols(a: &Tensor, d: &[f32]) -> Tensor {
    assert_eq!(a.ndim(), 2);
    let (m, n) = (a.shape()[0], a.shape()[1]);
    assert_eq!(n, d.len());
    let mut out = a.data().to_vec();
    for i in 0..m {
        for j in 0..n {
            out[i * n + j] *= d[j];
        }
    }
    Tensor::new(vec![m, n], out)
}

/// Max |Aᵀ·A − I| — orthonormality defect of the columns.
pub fn ortho_defect(a: &Tensor) -> f32 {
    let gram = matmul_tn(a, a);
    let n = gram.shape()[0];
    let mut worst = 0.0f32;
    for i in 0..n {
        for j in 0..n {
            let want = if i == j { 1.0 } else { 0.0 };
            worst = worst.max((gram.at2(i, j) - want).abs());
        }
    }
    worst
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::{assert_close, prop};

    #[test]
    fn matmul_small() {
        let a = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let b = Tensor::new(vec![3, 2], vec![7., 8., 9., 10., 11., 12.]);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[58., 64., 139., 154.]);
    }

    #[test]
    fn matmul_identity_prop() {
        prop("A·I == A", 20, |rng| {
            let m = rng.range(1, 8);
            let n = rng.range(1, 8);
            let a = Tensor::new(vec![m, n], rng.normal_vec(m * n, 1.0));
            let c = matmul(&a, &Tensor::eye(n));
            assert_close(c.data(), a.data(), 1e-6, 1e-6)
        });
    }

    #[test]
    fn nt_tn_match_explicit_transpose() {
        prop("matmul_nt/tn", 20, |rng| {
            let m = rng.range(1, 7);
            let k = rng.range(1, 7);
            let n = rng.range(1, 7);
            let a = Tensor::new(vec![m, k], rng.normal_vec(m * k, 1.0));
            let b = Tensor::new(vec![n, k], rng.normal_vec(n * k, 1.0));
            let c1 = matmul_nt(&a, &b);
            let c2 = matmul(&a, &b.transpose2());
            assert_close(c1.data(), c2.data(), 1e-5, 1e-5)?;
            let at = Tensor::new(vec![k, m], rng.normal_vec(k * m, 1.0));
            let bt = Tensor::new(vec![k, n], rng.normal_vec(k * n, 1.0));
            let d1 = matmul_tn(&at, &bt);
            let d2 = matmul(&at.transpose2(), &bt);
            assert_close(d1.data(), d2.data(), 1e-5, 1e-5)
        });
    }

    #[test]
    fn matvec_matches_matmul() {
        prop("matvec", 20, |rng| {
            let m = rng.range(1, 9);
            let k = rng.range(1, 9);
            let a = Tensor::new(vec![m, k], rng.normal_vec(m * k, 1.0));
            let x = rng.normal_vec(k, 1.0);
            let y = matvec(&a, &x);
            let xm = Tensor::new(vec![k, 1], x);
            let y2 = matmul(&a, &xm);
            assert_close(&y, y2.data(), 1e-5, 1e-5)
        });
    }

    #[test]
    fn scale_cols_diag() {
        let a = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        let c = scale_cols(&a, &[10.0, 0.5]);
        assert_eq!(c.data(), &[10., 1., 30., 2.]);
    }
}
