//! Golden-file tests for the `clover check` diagnostics over the
//! seeded-bad fixture corpus in `tests/fixtures/check/`.
//!
//! Goldens are the compact [`Report::golden_lines`] form (`CODE severity
//! locus`) — stable under message rewording and fixture relocation while
//! still pinning which `CLV0xx` code fires where.  Re-bless after an
//! intentional change with `CLV_BLESS=1 cargo test --test check_golden`.

use std::path::{Path, PathBuf};

use clover::check::{self, ManifestCheckOpts, Report, ServeSpec};
use clover::model::Manifest;
use clover::serve::KvCodecSpec;

fn fixtures() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/check")
}

fn assert_golden(report: &mut Report, expected: &Path) {
    report.sort();
    let got = report.golden_lines();
    if std::env::var("CLV_BLESS").is_ok() {
        std::fs::write(expected, &got).unwrap();
        return;
    }
    let want = std::fs::read_to_string(expected)
        .unwrap_or_else(|e| panic!("missing golden {}: {e}", expected.display()));
    assert_eq!(
        got,
        want,
        "diagnostics drifted from {} — re-bless with CLV_BLESS=1 if intentional",
        expected.display()
    );
}

#[test]
fn manifest_fixture_corpus_matches_goldens() {
    let mut seen = 0;
    for entry in std::fs::read_dir(fixtures()).unwrap() {
        let dir = entry.unwrap().path();
        let expected = dir.join("manifest.expected");
        if !expected.is_file() {
            continue;
        }
        let mut report = Report::new();
        check::check_manifest_dir(&mut report, &dir, &ManifestCheckOpts::default());
        assert_golden(&mut report, &expected);
        seen += 1;
    }
    assert!(seen >= 12, "manifest fixture corpus shrank to {seen} cases");
}

#[test]
fn bench_fixture_corpus_matches_goldens() {
    let mut seen = 0;
    for entry in std::fs::read_dir(fixtures().join("bench")).unwrap() {
        let doc = entry.unwrap().path();
        if doc.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        let mut report = Report::new();
        check::check_bench_file(&mut report, doc.to_str().unwrap());
        assert_golden(&mut report, &doc.with_extension("expected"));
        seen += 1;
    }
    assert!(seen >= 4, "bench fixture corpus shrank to {seen} cases");
}

#[test]
fn run_config_fixtures_match_goldens() {
    let good = Manifest::load(fixtures().join("good")).unwrap();
    for name in ["bad_run_config", "warn_run_config"] {
        let path = fixtures().join(format!("{name}.toml"));
        let mut report = Report::new();
        check::check_run_config(&mut report, path.to_str().unwrap(), Some(&good));
        assert_golden(&mut report, &fixtures().join(format!("{name}.expected")));
    }
}

#[test]
fn good_fixture_is_diagnostic_free_and_loads() {
    let mut report = Report::new();
    let m = check::check_manifest_dir(
        &mut report,
        &fixtures().join("good"),
        &ManifestCheckOpts::default(),
    );
    assert!(report.is_empty(), "good fixture regressed:\n{}", report.render_text());
    assert!(m.is_some(), "good fixture must load through the typed Manifest too");
}

/// The committed `BENCH_history/` bootstrap snapshot must stay exit-0:
/// nulls are CLV045 warnings, never errors.
#[test]
fn committed_bench_history_has_no_errors() {
    let history = Path::new(env!("CARGO_MANIFEST_DIR")).join("../BENCH_history");
    let mut checked = 0;
    for entry in std::fs::read_dir(history).unwrap() {
        let doc = entry.unwrap().path();
        if doc.extension().and_then(|e| e.to_str()) != Some("json") {
            continue;
        }
        let mut report = Report::new();
        check::check_bench_file(&mut report, doc.to_str().unwrap());
        assert!(
            !report.has_errors(),
            "committed snapshot {} fails clover check:\n{}",
            doc.display(),
            report.render_text()
        );
        checked += 1;
    }
    assert!(checked >= 1, "BENCH_history lost its snapshot");
}

fn codes(report: &Report) -> Vec<String> {
    report.diagnostics().iter().map(|d| d.code_str()).collect()
}

/// Engine-spec combinations map to stable codes (the `<flags>` side of
/// the checker has no file fixtures; pin the codes directly).
#[test]
fn engine_spec_combinations_fire_stable_codes() {
    let m = Manifest::load(fixtures().join("good")).unwrap();
    let check_spec = |spec: &ServeSpec| {
        let mut report = Report::new();
        check::check_engine_spec(&mut report, &m, spec, "<flags>");
        report.sort();
        report
    };

    let unknown_preset = ServeSpec { preset: "nope".into(), ..Default::default() };
    assert_eq!(codes(&check_spec(&unknown_preset)), ["CLV020"]);

    let budgets_wrong_len = ServeSpec {
        kv_codec: KvCodecSpec::Factored { layer_budgets: Some(vec![2]) },
        ..Default::default()
    };
    assert_eq!(codes(&check_spec(&budgets_wrong_len)), ["CLV021"]);

    let budget_out_of_range = ServeSpec {
        kv_codec: KvCodecSpec::Factored { layer_budgets: Some(vec![9, 9]) },
        ..Default::default()
    };
    assert_eq!(codes(&check_spec(&budget_out_of_range)), ["CLV022"]);

    let rank_off_ladder = ServeSpec { rank: Some(3), ..Default::default() };
    assert_eq!(codes(&check_spec(&rank_off_ladder)), ["CLV024"]);

    let draft_len_too_small = ServeSpec {
        speculative: Some((4, clover::serve::SpecConfig { draft_len: 1, adaptive: true })),
        ..Default::default()
    };
    assert_eq!(codes(&check_spec(&draft_len_too_small)), ["CLV025"]);

    let sampled_speculation = ServeSpec {
        speculative: Some((4, clover::serve::SpecConfig { draft_len: 4, adaptive: true })),
        temperature: 0.7,
        ..Default::default()
    };
    assert_eq!(codes(&check_spec(&sampled_speculation)), ["CLV027"]);

    let draft_rank_not_cheaper = ServeSpec {
        speculative: Some((8, clover::serve::SpecConfig { draft_len: 4, adaptive: true })),
        ..Default::default()
    };
    assert_eq!(codes(&check_spec(&draft_rank_not_cheaper)), ["CLV024"]);

    let starved_ladder = ServeSpec { max_step_tokens: Some(4), ..Default::default() };
    let r = check_spec(&starved_ladder);
    assert_eq!(codes(&r), ["CLV028"]);
    assert!(!r.has_errors(), "CLV028 is a warning, not an error");

    let budget_below_one_page = ServeSpec { kv_memory_budget: Some(1), ..Default::default() };
    assert_eq!(codes(&check_spec(&budget_below_one_page)), ["CLV029"]);

    let budget_below_full_window = ServeSpec {
        kv_memory_budget: Some(10_000),
        ..Default::default()
    };
    let r = check_spec(&budget_below_full_window);
    assert_eq!(codes(&r), ["CLV030"]);
    assert!(!r.has_errors(), "CLV030 is a warning, not an error");

    let clean_speculative_pair = ServeSpec {
        rank: Some(4),
        speculative: Some((2, clover::serve::SpecConfig { draft_len: 4, adaptive: true })),
        kv_codec: KvCodecSpec::Factored { layer_budgets: Some(vec![2, 4]) },
        ..Default::default()
    };
    let r = check_spec(&clean_speculative_pair);
    assert!(r.is_empty(), "legal combination flagged:\n{}", r.render_text());

    // Scheduler-v2 prefix-cache flags (tiny fixture: 16-token pages at
    // 4096 B, ladder [8], 64-token window → full-window worst 16384 B).
    let prefix_block_misaligned = ServeSpec {
        prefix_cache_block: Some(24), // 24 % 16 != 0, though ladder rung 8 tiles it
        kv_memory_budget: Some(16_384),
        ..Default::default()
    };
    assert_eq!(codes(&check_spec(&prefix_block_misaligned)), ["CLV034"]);

    let prefix_beside_speculative = ServeSpec {
        prefix_cache_block: Some(32),
        speculative: Some((4, clover::serve::SpecConfig { draft_len: 4, adaptive: true })),
        kv_memory_budget: Some(1_000_000),
        ..Default::default()
    };
    assert_eq!(codes(&check_spec(&prefix_beside_speculative)), ["CLV035"]);

    let prefix_without_budget =
        ServeSpec { prefix_cache_block: Some(32), ..Default::default() };
    let r = check_spec(&prefix_without_budget);
    assert_eq!(codes(&r), ["CLV036"]);
    assert!(!r.has_errors(), "CLV036 is a warning, not an error");

    // Budget holds one resident page (no CLV029) but not one cached
    // 2-page block (8192 B) nor a full window — CLV030 + CLV036 co-fire.
    let prefix_budget_below_block = ServeSpec {
        prefix_cache_block: Some(32),
        kv_memory_budget: Some(4_096),
        ..Default::default()
    };
    assert_eq!(codes(&check_spec(&prefix_budget_below_block)), ["CLV030", "CLV036"]);

    let clean_prefix_cache = ServeSpec {
        prefix_cache_block: Some(32),
        kv_memory_budget: Some(16_384),
        ..Default::default()
    };
    let r = check_spec(&clean_prefix_cache);
    assert!(r.is_empty(), "legal prefix-cache flags flagged:\n{}", r.render_text());

    // Chaos / robustness flags (CLV037–CLV039).
    let fault_plan_unknown_key = ServeSpec {
        fault_plan: Some("seed=7,flaky=0.5".into()),
        ..Default::default()
    };
    assert_eq!(codes(&check_spec(&fault_plan_unknown_key)), ["CLV037"]);

    let fault_plan_rate_out_of_range = ServeSpec {
        fault_plan: Some("transient=1.5".into()),
        ..Default::default()
    };
    assert_eq!(codes(&check_spec(&fault_plan_rate_out_of_range)), ["CLV037"]);

    let clean_fault_plan = ServeSpec {
        fault_plan: Some("seed=7,transient=0.01,fatal-after=500".into()),
        ..Default::default()
    };
    let r = check_spec(&clean_fault_plan);
    assert!(r.is_empty(), "legal fault plan flagged:\n{}", r.render_text());

    let breaker_inverted = ServeSpec { breaker: Some((0.5, 0.1)), ..Default::default() };
    assert_eq!(codes(&check_spec(&breaker_inverted)), ["CLV038"]);

    let breaker_degraded_zero = ServeSpec { breaker: Some((0.0, 0.5)), ..Default::default() };
    assert_eq!(codes(&check_spec(&breaker_degraded_zero)), ["CLV038"]);

    let breaker_open_above_one = ServeSpec { breaker: Some((0.1, 1.5)), ..Default::default() };
    assert_eq!(codes(&check_spec(&breaker_open_above_one)), ["CLV038"]);

    let clean_breaker = ServeSpec { breaker: Some((0.1, 0.5)), ..Default::default() };
    let r = check_spec(&clean_breaker);
    assert!(r.is_empty(), "legal breaker thresholds flagged:\n{}", r.render_text());

    // 10 retries doubling from 100 ms: worst 102_300 ms of backoff, far
    // past a 1 s deadline — the request expires mid-backoff every time.
    let retry_starves_deadline = ServeSpec {
        retry_budget: 10,
        retry_backoff_ms: 100,
        deadline_ms: Some(1_000),
        ..Default::default()
    };
    let r = check_spec(&retry_starves_deadline);
    assert_eq!(codes(&r), ["CLV039"]);
    assert!(!r.has_errors(), "CLV039 is a warning, not an error");

    // Default policy (3 retries from 1 ms → 7 ms worst) fits easily.
    let feasible_retry = ServeSpec { deadline_ms: Some(1_000), ..Default::default() };
    let r = check_spec(&feasible_retry);
    assert!(r.is_empty(), "feasible retry-vs-deadline flagged:\n{}", r.render_text());

    // No deadline ⇒ nothing to be infeasible against, however large.
    let no_deadline = ServeSpec {
        retry_budget: 64, // also exercises the shl-overflow saturation path
        retry_backoff_ms: 60_000,
        ..Default::default()
    };
    let r = check_spec(&no_deadline);
    assert!(r.is_empty(), "retry policy without a deadline flagged:\n{}", r.render_text());
}

/// Seeded-bad chaos-flag combinations pinned as golden fixtures, like the
/// prefix-scheduler set above: CLV037–CLV039 wiring stays stable under
/// message rewording.
#[test]
fn chaos_flag_fixtures_match_goldens() {
    let m = Manifest::load(fixtures().join("good")).unwrap();
    let cases: [(&str, ServeSpec); 3] = [
        (
            "bad_fault_plan",
            ServeSpec {
                fault_plan: Some("transient=lots,spike-factor=0".into()),
                ..Default::default()
            },
        ),
        ("bad_breaker", ServeSpec { breaker: Some((0.9, 0.2)), ..Default::default() }),
        (
            "warn_retry_deadline",
            ServeSpec {
                retry_budget: 8,
                retry_backoff_ms: 50,
                deadline_ms: Some(2_000),
                ..Default::default()
            },
        ),
    ];
    for (name, spec) in cases {
        let mut report = Report::new();
        check::check_engine_spec(&mut report, &m, &spec, "<flags>");
        assert_golden(&mut report, &fixtures().join(format!("{name}.expected")));
    }
}

/// Seeded-bad scheduler-flag combinations pinned as golden fixtures, like
/// the manifest/bench corpus: the compact `CODE severity locus` form keeps
/// the CLV034–CLV036 wiring stable under message rewording.
#[test]
fn prefix_scheduler_flag_fixtures_match_goldens() {
    let m = Manifest::load(fixtures().join("good")).unwrap();
    let cases: [(&str, ServeSpec); 2] = [
        (
            "bad_prefix_flags",
            ServeSpec {
                prefix_cache_block: Some(24),
                speculative: Some((4, clover::serve::SpecConfig { draft_len: 4, adaptive: true })),
                ..Default::default()
            },
        ),
        (
            "warn_prefix_budget",
            ServeSpec {
                prefix_cache_block: Some(32),
                kv_memory_budget: Some(4_096),
                ..Default::default()
            },
        ),
    ];
    for (name, spec) in cases {
        let mut report = Report::new();
        check::check_engine_spec(&mut report, &m, &spec, "<flags>");
        assert_golden(&mut report, &fixtures().join(format!("{name}.expected")));
    }
}
