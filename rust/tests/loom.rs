//! Schedule-exploration models for the five protocols the serving spine
//! only property-tests elsewhere:
//!
//! 1. **Ingress admission vs cancel** — a cancel rides an unbounded
//!    channel and may beat its own submission; the registry must surface
//!    exactly one cancellation once the id is tracked, never zero, never
//!    two (`server/cancel.rs`).
//! 2. **Same-iteration KV-lane reclaim** — a lane freed by a terminal
//!    event must be allocatable by the same iteration's admission pass
//!    with conserved byte accounting (`serve/kv.rs`).
//! 3. **Speculative rollback vs slot free** — a rejected draft's rollback
//!    on one slot must not disturb a concurrent free of another slot;
//!    pages never resurrect, accounting never goes negative.
//! 4. **COW refcount decrement vs lane free** — prefix-cache eviction
//!    dropping its pins races the attached lane zeroing its table
//!    entries; every column is decremented exactly once per holder, frees
//!    exactly when the last reference lets go, and never resurrects
//!    (`serve/kv.rs`, `serve/prefix.rs`).
//! 5. **Worker death vs in-flight submit** — a terminal engine death
//!    sweeps the ingress and drops the receiver while a submit races the
//!    hand-off; the submission resolves exactly once — swept with one
//!    terminal, refused at send, or disconnected with its stream — never
//!    twice and never stranded (`server/gateway.rs`).
//!
//! With `--features loom` the shared state uses the loom types through
//! [`clover::util::sync`] and `loom::model` drives schedule exploration
//! (the vendored facade explores by seeded randomized yields; point the
//! workspace `loom` path at crates.io loom 0.7 for exhaustive DPOR — the
//! models are written against the real API).  Without the feature the
//! same models run as a plain 64-iteration stress loop, so `cargo test`
//! keeps covering the invariants on every push.

use std::time::Instant;

use clover::serve::{KvCodecSpec, KvConfig, KvManager, PagedKvStore, PAGE_TOKENS};
use clover::server::CancelRegistry;
use clover::util::sync::{thread, Arc, Mutex};

#[cfg(feature = "loom")]
use loom::model;

#[cfg(not(feature = "loom"))]
fn model<F>(f: F)
where
    F: Fn() + Sync + Send + 'static,
{
    for _ in 0..64 {
        f();
    }
}

fn lock<T>(m: &Mutex<T>) -> clover::util::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn two_slot_kv() -> KvManager {
    KvManager::new(KvConfig {
        n_layers: 2,
        n_heads: 2,
        rank: 4,
        max_positions: 4 * PAGE_TOKENS,
        batch_slots: 2,
        codec: KvCodecSpec::Identity,
    })
}

/// Protocol 1: cancel racing its own submission's hand-off.  Whichever
/// order the two sides land in, the id is surfaced exactly once and no
/// state leaks.
#[test]
fn admission_vs_cancel_surfaces_exactly_once() {
    model(|| {
        let reg = Arc::new(Mutex::new(CancelRegistry::new()));
        let canceller = {
            let reg = Arc::clone(&reg);
            thread::spawn(move || lock(&reg).cancel(7))
        };
        let admitter = {
            let reg = Arc::clone(&reg);
            thread::spawn(move || lock(&reg).track(7, None))
        };
        canceller.join().unwrap();
        admitter.join().unwrap();

        // The gateway's post-hand-off sweep: the cancel must fire now —
        // pre-cancels wait in the registry until the id is tracked.
        let due = lock(&reg).due(Instant::now());
        assert_eq!(due.len(), 1, "one cancellation for id 7, got {due:?}");
        assert_eq!(due[0].id, 7);
        assert!(lock(&reg).due(Instant::now()).is_empty(), "surfaced at most once");
        assert_eq!(lock(&reg).live(), 0, "no live state leaked");
    });
}

/// Protocol 2: a retiring lane frees while the admission pass allocates.
/// Both orders must succeed on a 2-slot batch with one slot occupied,
/// and the byte accounting must balance.
#[test]
fn same_iteration_lane_reclaim_conserves_slots() {
    model(|| {
        let kv = Arc::new(Mutex::new(two_slot_kv()));
        let occupied = {
            let mut kv = lock(&kv);
            let s = kv.allocate(1).unwrap();
            kv.advance_by(s, PAGE_TOKENS).unwrap();
            let s2 = kv.allocate(2).unwrap();
            kv.advance_by(s2, PAGE_TOKENS).unwrap();
            s
        };
        // Retirement frees request 1's lane...
        let retirer = {
            let kv = Arc::clone(&kv);
            thread::spawn(move || lock(&kv).free(occupied).unwrap())
        };
        // ...while admission tries to place request 3.  The batch is full
        // until the free lands, so admission spins — the same-iteration
        // reclaim the engine guarantees by running retirement first.
        let admitter = {
            let kv = Arc::clone(&kv);
            thread::spawn(move || loop {
                if let Ok(slot) = lock(&kv).allocate(3) {
                    return slot;
                }
                thread::yield_now();
            })
        };
        assert_eq!(retirer.join().unwrap(), 1, "freed lane belonged to request 1");
        let slot = admitter.join().unwrap();
        assert_eq!(slot, occupied, "admission reclaimed the freed lane");

        let kv = lock(&kv);
        assert_eq!(kv.free_slots(), 0, "both slots occupied after reclaim");
        assert_eq!(kv.live_pages(), 1, "request 3 has not advanced yet");
        assert_eq!(kv.freed_bytes(), kv.config().bytes_per_page());
    });
}

/// Protocol 3: speculative rollback on one slot racing a free of the
/// other.  The rollback must only ever shrink its own slot; the freed
/// slot's pages must not resurrect under any interleaving.
#[test]
fn speculative_rollback_vs_slot_free_is_isolated() {
    model(|| {
        let kv = Arc::new(Mutex::new(two_slot_kv()));
        let (verify_slot, other_slot) = {
            let mut kv = lock(&kv);
            let a = kv.allocate(1).unwrap();
            kv.advance_by(a, PAGE_TOKENS + 4).unwrap(); // draft ran ahead
            let b = kv.allocate(2).unwrap();
            kv.advance_by(b, PAGE_TOKENS).unwrap();
            (a, b)
        };
        // Verify rejected the tail of the draft: roll slot A back below
        // its page boundary...
        let roller = {
            let kv = Arc::clone(&kv);
            thread::spawn(move || lock(&kv).rollback_to(verify_slot, PAGE_TOKENS - 2).unwrap())
        };
        // ...while slot B's request hits its terminal event and frees.
        let freer = {
            let kv = Arc::clone(&kv);
            thread::spawn(move || lock(&kv).free(other_slot).unwrap())
        };
        roller.join().unwrap();
        freer.join().unwrap();

        let kv = lock(&kv);
        assert_eq!(kv.positions(verify_slot), PAGE_TOKENS - 2, "rollback landed");
        assert_eq!(kv.live_pages(), 1, "one page for the rolled-back slot, none resurrected");
        assert_eq!(kv.free_slots(), 1, "slot B stays free");
        assert_eq!(kv.live_bytes(), kv.config().bytes_per_page());
    });
}

/// Protocol 4: the prefix cache evicting its pins while the attached lane
/// frees.  Setup mirrors the engine: lane 0 prefilled two pages, the
/// cache pinned them (`share_prefix`), lane 1 attached them COW
/// (`attach_prefix`) — each column holds three references.  Eviction
/// (`release_cols`) and lane churn (`zero_lane`) then land in either
/// order; the columns must survive on exactly the donor's reference, free
/// exactly once when the donor lets go, and never resurrect.
#[test]
fn cow_refcount_decrement_vs_lane_free_frees_exactly_once() {
    model(|| {
        let codec = KvCodecSpec::Identity.build(2, 4).unwrap();
        let mut init = PagedKvStore::new(2, 2, 2, 2 * PAGE_TOKENS, 2, codec);
        init.write_vec(0, 0, 0, 0, 0, &[1.0, 2.0, 3.0, 4.0]); // donor prefill
        init.write_vec(0, 0, 0, 0, PAGE_TOKENS, &[5.0, 6.0, 7.0, 8.0]);
        let cols = init.share_prefix(0, 2); // cache pins: refs 2 + 2
        init.attach_prefix(1, &cols).unwrap(); // hit lane: refs 3 + 3
        let store = Arc::new(Mutex::new(init));

        // LRU eviction under memory pressure drops the cache's pins...
        let evictor = {
            let store = Arc::clone(&store);
            let cols = cols.clone();
            thread::spawn(move || lock(&store).release_cols(&cols))
        };
        // ...while the attached request cancels mid-prefill and its lane
        // zeroes — the exact race the engine runs between decode steps.
        let laner = {
            let store = Arc::clone(&store);
            thread::spawn(move || lock(&store).zero_lane(1))
        };
        evictor.join().unwrap();
        laner.join().unwrap();

        {
            let store = lock(&store);
            for &c in &cols {
                assert_eq!(store.col_refs(c), 1, "only the donor lane still holds column {c}");
            }
            assert_eq!(store.live_columns(), 2, "both pages survive on the donor's reference");
        }
        // The donor retires last: every column frees now, and a stale
        // attach on the freed ids must refuse — no resurrection.
        let mut store = lock(&store);
        store.zero_lane(0);
        for &c in &cols {
            assert_eq!(store.col_refs(c), 0, "column {c} freed with its last reference");
        }
        assert_eq!(store.live_columns(), 0, "nothing resurrected");
        assert_eq!(store.stored_bytes(), 0, "all buffers returned");
        assert!(
            store.attach_prefix(1, &cols).is_err(),
            "attaching freed columns must refuse, not resurrect"
        );
    });
}

/// Protocol 5: worker death racing an in-flight submit (`gateway.rs`
/// `engine_lost` + worker exit vs `submit_inner`).  The ingress is a
/// bounded channel only the worker can drain; on a terminal engine death
/// the worker sweeps it (terminal `Failed`/park for everything buffered),
/// then exits, dropping the receiver — after which a send fails back to
/// the submitter, who never got a ticket.  The race window is a send
/// landing *between* the final sweep and the receiver drop: that
/// submission is dropped with the channel, which closes its event stream
/// — the client's `wait()` observes the closure as an error.  Whichever
/// interleaving runs, the submission must land in **exactly one** bucket:
/// swept (one terminal event), refused (send error, no ticket state), or
/// disconnected (stream closed, no terminal) — never two, never none
/// (none would be a client hung on a stream nobody will ever feed).
#[test]
fn worker_death_vs_inflight_submit_resolves_exactly_once() {
    /// The ingress as the worker and submitter both see it: the buffered
    /// queue plus whether the receiver is still alive.
    struct Ingress {
        queue: Vec<u64>,
        open: bool,
    }

    model(|| {
        let ingress = Arc::new(Mutex::new(Ingress { queue: Vec::new(), open: true }));
        // Terminal-`Failed` ids from the death sweep (order irrelevant).
        let swept = Arc::new(Mutex::new(Vec::<u64>::new()));
        // Ids dropped with the receiver — their event stream closed.
        let disconnected = Arc::new(Mutex::new(Vec::<u64>::new()));

        // Submitter: `submit_inner`'s send against a possibly-dying
        // worker.  Returns whether a ticket was issued.
        let submitter = {
            let ingress = Arc::clone(&ingress);
            thread::spawn(move || {
                let mut ch = lock(&ingress);
                if ch.open {
                    ch.queue.push(7);
                    true // send succeeded: the caller holds a live ticket
                } else {
                    false // SubmitError::Closed: no id, no stream
                }
            })
        };

        // Worker death path: `engine_lost` sweeps the ingress (delivering
        // a terminal per buffered submission), the supervisor loop runs
        // one more sweep on the way out (shutdown drain — a swept id must
        // NOT get a second terminal), then the receiver drops: the
        // channel closes and anything still buffered disconnects.
        let worker = {
            let ingress = Arc::clone(&ingress);
            let swept = Arc::clone(&swept);
            let disconnected = Arc::clone(&disconnected);
            thread::spawn(move || {
                for _ in 0..2 {
                    let drained: Vec<u64> = lock(&ingress).queue.drain(..).collect();
                    lock(&swept).extend(drained);
                }
                let mut ch = lock(&ingress);
                ch.open = false;
                lock(&disconnected).extend(ch.queue.drain(..));
            })
        };

        let ticketed = submitter.join().unwrap();
        worker.join().unwrap();

        let swept = lock(&swept);
        let disconnected = lock(&disconnected);
        assert!(lock(&ingress).queue.is_empty(), "nothing may stay buffered past death");
        let terminals = swept.iter().filter(|&&id| id == 7).count();
        let closures = disconnected.iter().filter(|&&id| id == 7).count();
        if ticketed {
            assert_eq!(
                terminals + closures,
                1,
                "a ticketed submission resolves exactly once \
                 (terminals {terminals}, closures {closures})"
            );
        } else {
            assert_eq!(
                (terminals, closures),
                (0, 0),
                "a refused submission left state behind"
            );
        }
    });
}
