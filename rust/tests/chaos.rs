//! Chaos property tests over the public serving API: whatever the fault
//! plan throws at a fleet, the client-side ledger must balance.
//!
//! Two invariants, held across a matrix of fault seeds:
//!
//! * **Conservation** — every request accepted by `submit` receives
//!   exactly one terminal outcome (`Done` / `Cancelled` / `Failed`):
//!   `done + cancelled + failed == accepted`, counted from the client's
//!   own streams, not the server's metrics.
//! * **Lossless recovery** — a request that completes despite transient
//!   faults, engine deaths, restarts, or cross-engine failover produces
//!   a token row *bit-identical* to a fault-free serve of the same
//!   prompt: the stub's logits are a pure function of `(model seed, lane
//!   token history)`, so replay-from-`prompt ⧺ streamed` resumes the
//!   exact decode.
//!
//! The fault schedules themselves are pure functions of `(fault seed,
//!   step)` — see `docs/ROBUSTNESS.md` — so every case here is
//! deterministic per seed; `CLOVER_FAULT_SEED` does *not* apply (the
//! plans are constructed directly, not parsed from flags).

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

use clover::runtime::stub::{FaultPlan, StubSpec};
use clover::serve::SamplingParams;
use clover::server::{EngineSpec, Gateway, GatewayConfig, Router, StreamOutcome};

/// The chaos seed matrix (the CI lane sweeps the same values through
/// `CLOVER_FAULT_SEED` for the in-module suites).
const SEEDS: [u64; 3] = [1, 7, 42];
const REQUESTS: usize = 6;
const MAX_NEW: usize = 8;

fn prompt(i: usize) -> Vec<i32> {
    vec![10 + i as i32, 2, 3]
}

fn spawn(name: &str, cfg: GatewayConfig, spec: StubSpec) -> Gateway {
    Gateway::spawn(name, cfg, EngineSpec::stub(spec)).expect("gateway spawns")
}

/// Fault-free reference rows, keyed by the prompt's distinguishing first
/// token — the oracle every recovered serve is compared against.
fn reference_rows() -> HashMap<i32, Vec<i32>> {
    let gw = spawn("chaos-ref", GatewayConfig::default(), StubSpec::default());
    let tickets: Vec<_> = (0..REQUESTS)
        .map(|i| {
            gw.submit(prompt(i), MAX_NEW, SamplingParams::greedy(), None).expect("submit")
        })
        .collect();
    let rows: HashMap<i32, Vec<i32>> = tickets
        .into_iter()
        .map(|t| match t.stream.wait().expect("terminal event") {
            StreamOutcome::Done(c) => (c.tokens[0], c.tokens),
            other => panic!("fault-free reference did not complete: {other:?}"),
        })
        .collect();
    gw.join().expect("clean shutdown");
    assert_eq!(rows.len(), REQUESTS, "reference prompts must be distinct");
    rows
}

/// Client-side ledger for one serve: wait out every stream (the wait
/// itself asserts a terminal arrived — a stream closed without one is an
/// `Err`) and bucket the outcomes.
struct Ledger {
    done: Vec<Vec<i32>>,
    cancelled: usize,
    failed: usize,
}

fn drain(tickets: Vec<clover::server::Ticket>) -> Ledger {
    let mut ledger = Ledger { done: Vec::new(), cancelled: 0, failed: 0 };
    for t in tickets {
        match t.stream.wait().expect("every accepted request gets a terminal event") {
            StreamOutcome::Done(c) => ledger.done.push(c.tokens),
            StreamOutcome::Cancelled { .. } => ledger.cancelled += 1,
            StreamOutcome::Failed { .. } => ledger.failed += 1,
        }
    }
    ledger
}

fn assert_bit_identical(rows: &[Vec<i32>], want: &HashMap<i32, Vec<i32>>) {
    for row in rows {
        let reference = want
            .get(&row[0])
            .unwrap_or_else(|| panic!("completed row has unknown prompt head {}", row[0]));
        assert_eq!(row, reference, "recovered decode diverged from the fault-free serve");
    }
}

/// Transient faults under retry plus a mid-serve worker panic under the
/// supervisor: every request completes, bit-identically, at every seed.
#[test]
fn supervised_recovery_is_lossless_across_seeds() {
    let want = reference_rows();
    for seed in SEEDS {
        let plan = FaultPlan {
            seed,
            transient_rate: 0.05,
            crash_after_steps: Some(6),
            ..Default::default()
        };
        let spec = StubSpec {
            // Slow steps so all submits land before the scheduled crash.
            step_delay: Duration::from_millis(2),
            fault_plan: plan,
            ..Default::default()
        };
        let cfg = GatewayConfig { max_restarts: 3, ..Default::default() };
        let gw = spawn(&format!("chaos-sup-{seed}"), cfg, spec);
        let tickets: Vec<_> = (0..REQUESTS)
            .map(|i| {
                gw.submit(prompt(i), MAX_NEW, SamplingParams::greedy(), None).expect("submit")
            })
            .collect();
        let ledger = drain(tickets);
        assert_eq!(
            (ledger.done.len(), ledger.cancelled, ledger.failed),
            (REQUESTS, 0, 0),
            "seed {seed}: supervised recovery lost or failed a request"
        );
        assert_bit_identical(&ledger.done, &want);
        gw.join().expect("supervised gateway drains cleanly");
    }
}

/// A mixed storm — transient faults *and* poisoned logits — against the
/// conservation ledger: poisoned lanes may fail their one request, but
/// every stream still terminates, the counts balance, and whatever did
/// complete is bit-identical.
#[test]
fn conservation_holds_under_mixed_fault_storm() {
    let want = reference_rows();
    for seed in SEEDS {
        let plan = FaultPlan {
            seed,
            transient_rate: 0.2,
            poison_rate: 0.05,
            ..Default::default()
        };
        let spec = StubSpec {
            step_delay: Duration::from_millis(1),
            fault_plan: plan,
            ..Default::default()
        };
        let cfg = GatewayConfig { max_restarts: 2, ..Default::default() };
        let gw = spawn(&format!("chaos-storm-{seed}"), cfg, spec);
        let tickets: Vec<_> = (0..REQUESTS)
            .map(|i| {
                gw.submit(prompt(i), MAX_NEW, SamplingParams::greedy(), None).expect("submit")
            })
            .collect();
        let ledger = drain(tickets);
        assert_eq!(
            ledger.done.len() + ledger.cancelled + ledger.failed,
            REQUESTS,
            "seed {seed}: ledger does not balance"
        );
        assert_eq!(ledger.cancelled, 0, "seed {seed}: nothing was cancelled");
        assert_bit_identical(&ledger.done, &want);
        // The worker may legitimately die if the storm outlives the
        // restart budget — conservation above is the contract, not a
        // clean join.
        let _ = gw.join();
    }
}

/// The guaranteed-worst storm: every step faults, every retry faults,
/// every replay faults.  Deterministic at any seed — the restart budget
/// is spent and *every* request must come back `Failed`, never hang.
#[test]
fn total_fault_storm_fails_everything_terminally() {
    let plan = FaultPlan { seed: 1, transient_rate: 1.0, ..Default::default() };
    let spec = StubSpec {
        step_delay: Duration::from_millis(2),
        fault_plan: plan,
        ..Default::default()
    };
    let cfg = GatewayConfig { max_restarts: 1, ..Default::default() };
    let gw = spawn("chaos-total", cfg, spec);
    let tickets: Vec<_> = (0..REQUESTS)
        .map(|i| gw.submit(prompt(i), MAX_NEW, SamplingParams::greedy(), None).expect("submit"))
        .collect();
    let ledger = drain(tickets);
    assert_eq!(
        (ledger.done.len(), ledger.cancelled, ledger.failed),
        (0, 0, REQUESTS),
        "a dead-on-arrival backend must fail every request terminally"
    );
    assert!(gw.join().is_err(), "the spent restart budget surfaces the underlying error");
}

/// Fleet failover: one engine is scheduled to die for good
/// (`max_restarts: 0`, orphan parking on), its sibling shares the stub
/// model seed.  `Router::fail_over` re-homes the orphans and every
/// request completes bit-identically — the ledger balances across the
/// *fleet*, not per engine.
#[test]
fn fleet_failover_preserves_every_request() {
    let want = reference_rows();
    let doomed_spec = StubSpec {
        step_delay: Duration::from_millis(2),
        fault_plan: FaultPlan { seed: 1, fatal_after_steps: Some(4), ..Default::default() },
        ..Default::default()
    };
    let doomed = spawn(
        "chaos-fo-a",
        GatewayConfig { max_restarts: 0, failover: true, ..Default::default() },
        doomed_spec,
    );
    let sibling = spawn("chaos-fo-b", GatewayConfig::default(), StubSpec::default());
    let router = Router::new(vec![doomed, sibling]).expect("router builds");

    let tickets: Vec<_> = (0..REQUESTS)
        .map(|i| {
            let (_, t) = router
                .submit(prompt(i), MAX_NEW, SamplingParams::greedy(), None)
                .expect("router submit");
            t
        })
        .collect();

    // The failover sweep needs a live caller while the client side blocks
    // in `wait`: poll it from a scoped sidecar until the streams drain.
    let drained = AtomicBool::new(false);
    let ledger = std::thread::scope(|s| {
        s.spawn(|| {
            while !drained.load(Ordering::SeqCst) {
                router.fail_over();
                std::thread::sleep(Duration::from_millis(5));
            }
        });
        let ledger = drain(tickets);
        drained.store(true, Ordering::SeqCst);
        ledger
    });

    assert_eq!(
        (ledger.done.len(), ledger.cancelled, ledger.failed),
        (REQUESTS, 0, 0),
        "failover lost or failed a request"
    );
    assert_bit_identical(&ledger.done, &want);
    // The doomed worker died by design; the router's join surfaces it.
    assert!(router.join().is_err(), "the dead engine's error must not be swallowed");
}
