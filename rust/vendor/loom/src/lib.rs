//! Offline stand-in for [loom](https://docs.rs/loom) 0.7.
//!
//! This environment vendors every dependency (see the workspace
//! `vendor/` convention started by the `xla` stub), so the real loom —
//! which would arrive from crates.io — is replaced by an API-compatible
//! facade.  The contract:
//!
//! * [`model`] runs the closure [`ITERS`] times, each under a distinct
//!   deterministic schedule seed.
//! * The [`sync`] primitives wrap their `std` twins and call
//!   [`preempt`] at every acquisition point, so each iteration explores
//!   a *different* interleaving of the modeled threads.
//!
//! That makes a facade run a seeded schedule-randomizing stress test —
//! strictly weaker than loom's exhaustive DPOR exploration, but honest:
//! the models in `tests/loom.rs` are written against the real loom API,
//! and pointing the workspace `loom` path dependency at a crates.io
//! checkout upgrades them to exhaustive checking with zero source
//! changes.  Assertion failures reproduce from the iteration's seed
//! because preemption decisions are drawn from a process-global
//! sequence, not from wall-clock or OS scheduling noise.
//!
//! Only the slice of loom's surface the repo's models need is provided:
//! `model`, `thread::{spawn, yield_now, JoinHandle}`,
//! `sync::{Arc, Mutex, MutexGuard, Condvar}`, and `sync::atomic`
//! re-exports.  Extend it as models grow.

use std::sync::atomic::{AtomicU64, Ordering};

/// Iterations per [`model`] call.  Each gets its own schedule seed.
pub const ITERS: usize = 64;

/// Process-global schedule state: a splitmix64-style sequence advanced
/// at every preemption point.  Reseeded per model iteration.
static SCHED: AtomicU64 = AtomicU64::new(0x9e37_79b9_7f4a_7c15);

/// Schedule-exploration point: deterministically decide whether the
/// current thread yields here.  No-op cost when it does not.  Public so
/// shims can add explicit exploration points, mirroring
/// `loom::thread::yield_now` placement advice.
pub fn preempt() {
    let mut x = SCHED.fetch_add(0x2545_f491_4f6c_dd1d, Ordering::Relaxed);
    x ^= x >> 33;
    x = x.wrapping_mul(0xff51_afd7_ed55_8ccd);
    x ^= x >> 29;
    if x % 3 == 0 {
        std::thread::yield_now();
    }
}

/// Run `f` under the model checker: [`ITERS`] schedule-randomized
/// executions.  (Real loom explores every interleaving instead.)
pub fn model<F>(f: F)
where
    F: Fn() + Sync + Send + 'static,
{
    for i in 0..ITERS {
        let seed = 0x9e37_79b9_7f4a_7c15u64 ^ (i as u64).wrapping_mul(0xa076_1d64_78bd_642f);
        SCHED.store(seed, Ordering::Relaxed);
        f();
    }
}

pub mod thread {
    pub use std::thread::{yield_now, JoinHandle};

    /// `std::thread::spawn` with a preemption point at thread start, so
    /// spawn-order races are explored too.
    pub fn spawn<F, T>(f: F) -> JoinHandle<T>
    where
        F: FnOnce() -> T + Send + 'static,
        T: Send + 'static,
    {
        std::thread::spawn(move || {
            super::preempt();
            f()
        })
    }

    /// Named-thread builder (the gateway names its worker threads).
    #[derive(Debug)]
    pub struct Builder(std::thread::Builder);

    impl Default for Builder {
        fn default() -> Self {
            Self::new()
        }
    }

    impl Builder {
        pub fn new() -> Self {
            Self(std::thread::Builder::new())
        }

        pub fn name(self, name: String) -> Self {
            Self(self.0.name(name))
        }

        pub fn spawn<F, T>(self, f: F) -> std::io::Result<JoinHandle<T>>
        where
            F: FnOnce() -> T + Send + 'static,
            T: Send + 'static,
        {
            self.0.spawn(move || {
                super::preempt();
                f()
            })
        }
    }
}

pub mod sync {
    use std::sync::LockResult;

    // Loom's `Arc` additionally tracks causality; the std one is an
    // API-compatible stand-in for the facade's purposes.
    pub use std::sync::Arc;

    pub type MutexGuard<'a, T> = std::sync::MutexGuard<'a, T>;

    /// `std::sync::Mutex` with a schedule-exploration point before every
    /// acquisition.
    #[derive(Debug, Default)]
    pub struct Mutex<T>(std::sync::Mutex<T>);

    impl<T> Mutex<T> {
        pub fn new(t: T) -> Self {
            Self(std::sync::Mutex::new(t))
        }

        pub fn lock(&self) -> LockResult<MutexGuard<'_, T>> {
            super::preempt();
            self.0.lock()
        }

        pub fn into_inner(self) -> LockResult<T> {
            self.0.into_inner()
        }

        pub fn get_mut(&mut self) -> LockResult<&mut T> {
            self.0.get_mut()
        }
    }

    /// `std::sync::Condvar` with exploration points around wait/notify.
    #[derive(Debug, Default)]
    pub struct Condvar(std::sync::Condvar);

    impl Condvar {
        pub fn new() -> Self {
            Self(std::sync::Condvar::new())
        }

        pub fn wait<'a, T>(&self, guard: MutexGuard<'a, T>) -> LockResult<MutexGuard<'a, T>> {
            super::preempt();
            self.0.wait(guard)
        }

        pub fn wait_while<'a, T, F>(
            &self,
            guard: MutexGuard<'a, T>,
            condition: F,
        ) -> LockResult<MutexGuard<'a, T>>
        where
            F: FnMut(&mut T) -> bool,
        {
            super::preempt();
            self.0.wait_while(guard, condition)
        }

        pub fn notify_one(&self) {
            self.0.notify_one();
            super::preempt();
        }

        pub fn notify_all(&self) {
            self.0.notify_all();
            super::preempt();
        }
    }

    pub mod atomic {
        // Atomics pass through unwrapped: the facade's exploration
        // points live at lock/spawn boundaries.  (Real loom wraps these
        // too and additionally checks orderings.)
        pub use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
    }
}
