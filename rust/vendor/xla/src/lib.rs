//! Build-compatible stub of the `xla` (xla-rs / PJRT) bindings.
//!
//! The CLOVER runtime executes AOT-lowered HLO through the PJRT C API via
//! the `xla` crate.  Those bindings link the XLA runtime and are not
//! vendorable as source here, so this stub stands in with the exact API
//! surface `clover` uses:
//!
//! * **Host-side [`Literal`]s are fully functional** — shape + dtype +
//!   byte storage, `create_from_shape_and_untyped_data`, `array_shape`,
//!   `to_vec`, tuple introspection.  Everything in
//!   `clover::runtime::literal` (and its tests) works for real.
//! * **Device entry points fail loudly** — [`PjRtClient::cpu`],
//!   [`PjRtLoadedExecutable::execute`], HLO parsing and `.npz` reading all
//!   return a descriptive [`Error`], so `Runtime::new` fails with a clear
//!   message and runtime-gated tests skip themselves
//!   (`clover::testing::runtime_or_skip`).
//!
//! To run against a live backend, point the `xla` path dependency in
//! `rust/Cargo.toml` at the real bindings (the crate this stub mirrors);
//! no `clover` source changes are required.

use std::borrow::Borrow;
use std::path::Path;

/// Stub error: a message explaining that the real PJRT bindings are not
/// present.  The real crate's error is also consumed via `{:?}` only.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn stub_err(what: &str) -> Error {
    Error(format!(
        "xla stub: {what} requires the real PJRT bindings; point the `xla` \
         path dependency in rust/Cargo.toml at them to run artifacts"
    ))
}

/// Element dtypes the manifest/literals speak.  Only F32/S32 flow through
/// clover today; the remaining variants keep dtype matches honest (and the
/// wildcard arms reachable).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S8,
    S32,
    S64,
    U8,
    U32,
    U64,
    F16,
    F32,
    F64,
    Bf16,
}

impl ElementType {
    fn byte_size(self) -> usize {
        match self {
            ElementType::Pred | ElementType::S8 | ElementType::U8 => 1,
            ElementType::F16 | ElementType::Bf16 => 2,
            ElementType::S32 | ElementType::U32 | ElementType::F32 => 4,
            ElementType::S64 | ElementType::U64 | ElementType::F64 => 8,
        }
    }
}

/// Shape of an array literal: dims (i64, as in the real bindings) + dtype.
#[derive(Clone, Debug)]
pub struct ArrayShape {
    dims: Vec<i64>,
    ty: ElementType,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// Native Rust types a literal's bytes can be viewed as.
pub trait ArrayElement: Copy + Sized {
    const TY: ElementType;
    fn read_le(bytes: &[u8]) -> Self;
}

impl ArrayElement for f32 {
    const TY: ElementType = ElementType::F32;
    fn read_le(bytes: &[u8]) -> Self {
        f32::from_le_bytes(bytes.try_into().expect("4-byte chunk"))
    }
}

impl ArrayElement for i32 {
    const TY: ElementType = ElementType::S32;
    fn read_le(bytes: &[u8]) -> Self {
        i32::from_le_bytes(bytes.try_into().expect("4-byte chunk"))
    }
}

impl ArrayElement for f64 {
    const TY: ElementType = ElementType::F64;
    fn read_le(bytes: &[u8]) -> Self {
        f64::from_le_bytes(bytes.try_into().expect("8-byte chunk"))
    }
}

impl ArrayElement for i64 {
    const TY: ElementType = ElementType::S64;
    fn read_le(bytes: &[u8]) -> Self {
        i64::from_le_bytes(bytes.try_into().expect("8-byte chunk"))
    }
}

enum Repr {
    Array { shape: ArrayShape, data: Vec<u8> },
    Tuple(Vec<Literal>),
}

/// A host-side literal.  Fully functional in the stub (the real crate
/// additionally hands these across the PJRT boundary).
pub struct Literal(Repr);

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        let n: usize = dims.iter().product();
        let want = n * ty.byte_size();
        if data.len() != want {
            return Err(Error(format!(
                "literal {dims:?} of {ty:?}: expected {want} bytes, got {}",
                data.len()
            )));
        }
        Ok(Literal(Repr::Array {
            shape: ArrayShape { dims: dims.iter().map(|&d| d as i64).collect(), ty },
            data: data.to_vec(),
        }))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match &self.0 {
            Repr::Array { shape, .. } => Ok(shape.clone()),
            Repr::Tuple(_) => Err(Error("array_shape of a tuple literal".into())),
        }
    }

    pub fn to_vec<T: ArrayElement>(&self) -> Result<Vec<T>> {
        match &self.0 {
            Repr::Array { shape, data } => {
                if shape.ty != T::TY {
                    return Err(Error(format!(
                        "to_vec dtype mismatch: literal is {:?}",
                        shape.ty
                    )));
                }
                Ok(data
                    .chunks_exact(shape.ty.byte_size())
                    .map(T::read_le)
                    .collect())
            }
            Repr::Tuple(_) => Err(Error("to_vec of a tuple literal".into())),
        }
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.0 {
            Repr::Tuple(parts) => Ok(parts),
            Repr::Array { .. } => Err(Error("to_tuple of an array literal".into())),
        }
    }
}

/// Raw-bytes constructors; in the real crate this trait also backs `.npz`
/// fixture loading, which needs numpy parsing the stub does not carry.
pub trait FromRawBytes: Sized {
    type Context: ?Sized;

    fn read_npz<P: AsRef<Path>>(path: P, ctx: &Self::Context) -> Result<Vec<(String, Self)>>;
}

impl FromRawBytes for Literal {
    type Context = ();

    fn read_npz<P: AsRef<Path>>(path: P, _ctx: &()) -> Result<Vec<(String, Self)>> {
        Err(stub_err(&format!("reading npz {:?}", path.as_ref())))
    }
}

/// Parsed HLO module; the stub cannot parse HLO text.
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<Self> {
        Err(stub_err(&format!("parsing HLO text {:?}", path.as_ref())))
    }
}

pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _priv: () }
    }
}

/// Device buffer returned by an execution (never constructed in the stub).
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(stub_err("fetching a device buffer"))
    }
}

pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(stub_err("executing a compiled program"))
    }
}

pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    /// Always errors in the stub: there is no PJRT runtime to attach to.
    pub fn cpu() -> Result<Self> {
        Err(stub_err("creating a PJRT CPU client"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(stub_err("compiling a computation"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let vals = [1.0f32, -2.5, 3.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[3]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vals);
        assert!(lit.to_vec::<i32>().is_err(), "dtype mismatch must fail");
    }

    #[test]
    fn literal_size_checked() {
        assert!(
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[2, 2], &[0u8; 15])
                .is_err()
        );
    }

    #[test]
    fn device_paths_fail_loudly() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo").is_err());
        let err = PjRtClient::cpu().unwrap_err();
        assert!(format!("{err}").contains("real PJRT bindings"));
    }
}
