//! Bench: regenerate Fig 2 (per-head importance spectra, CLOVER vs vanilla).
use clover::coordinator::experiments::{self, ExpOpts};
use clover::runtime::Runtime;
use clover::util::Stopwatch;

fn main() -> anyhow::Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let sw = Stopwatch::new();
    let rt = Runtime::new("artifacts")?;
    let opts = ExpOpts { preset: "tiny".into(), quick: !full, seed: 42 };
    let table = experiments::fig2(&rt, &opts, full)?;
    // Summarize: crossover point per head (the red dot of Fig 2).
    table.emit("fig2_spectra")?;
    println!("[fig2_spectra] total {:.1}s", sw.elapsed_s());
    Ok(())
}
