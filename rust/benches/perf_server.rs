//! Perf bench: the streaming server front-end, measured — not asserted.
//!
//! Three experiments, one JSON artifact (`BENCH_server.json`):
//!
//! 1. **Streaming vs wave-end delivery.**  The same trace served through
//!    the gateway (tokens delivered as sampled) and through
//!    `Engine::serve_all` (everything delivered when the call returns).
//!    Records each request's first-token receipt time under streaming
//!    against the batch-return wall of `serve_all`.
//! 2. **Cancel → reclaim.**  All KV lanes busy plus one queued waiter;
//!    a cancel token fires mid-decode.  Records the decode step the
//!    victim's lane freed at and the step the waiter started at — the
//!    gap is the reclaim latency in decode steps.
//! 3. **Rank-aware routing.**  One open-loop trace across dense/r=8/r=4
//!    gateways; per-rank shares, tokens/s, and peak KV bytes.
//!
//! A fourth experiment, `stub_streaming`, drives the same gateway stack
//! over the deterministic stub backend (48-token prompts through the
//! chunked-prefill slab ladder) and therefore runs on *every* checkout.
//! When no live PJRT backend or artifacts exist (vendored xla stub, bare
//! checkout), the three artifact-backed experiments are skipped
//! (`skipped: true`) but `BENCH_server.json` still carries real numbers,
//! so CI always uploads a meaningful artifact.

use anyhow::Result;
use clover::config::json::{self, Json};
use clover::runtime::stub::StubSpec;
use clover::runtime::Runtime;
use clover::serve::SamplingParams;
use clover::server::{EngineSpec, Gateway, GatewayConfig, StreamEvent};
use clover::util::human_bytes;
use std::collections::BTreeMap;
use std::thread;
use std::time::{Duration, Instant};

const ARTIFACTS: &str = "artifacts";
const PRESET: &str = "tiny";
const BATCH_SLOTS: usize = 8;
const SEED: i32 = 1;
/// 2× the slot count, mixed lengths — the continuous-batching regime.
const N_REQUESTS: u64 = 16;

fn trace_max_new(id: u64) -> usize {
    4 + (id as usize % 4) * 6
}

fn gw_config() -> GatewayConfig {
    GatewayConfig { queue_capacity: 128, ..Default::default() }
}

/// Per-request collector: receipt times measured on the consumer side, so
/// "delivered" means what a client would see, not what the engine sampled.
struct Collected {
    id: u64,
    first_token_s: Option<f64>,
    started_step: Option<usize>,
    terminal_step: Option<usize>,
    done: bool,
    generated: usize,
    /// Fused steps the request's prompt took (from its completion).
    prefill_steps: Option<usize>,
}

fn collect(stream: clover::server::RequestStream, t0: Instant) -> Collected {
    collect_notify(stream, t0, None)
}

/// Like [`collect`], additionally signalling `notify` on the first token —
/// how the cancel bench knows its victim is mid-decode before firing.
fn collect_notify(
    stream: clover::server::RequestStream,
    t0: Instant,
    notify: Option<std::sync::mpsc::Sender<()>>,
) -> Collected {
    let mut c = Collected {
        id: stream.id(),
        first_token_s: None,
        started_step: None,
        terminal_step: None,
        done: false,
        generated: 0,
        prefill_steps: None,
    };
    while let Some(ev) = stream.next_event() {
        match ev {
            StreamEvent::Started { step, .. } => c.started_step = Some(step),
            StreamEvent::Token { .. } => {
                c.generated += 1;
                if c.first_token_s.is_none() {
                    c.first_token_s = Some(t0.elapsed().as_secs_f64());
                    if let Some(tx) = &notify {
                        let _ = tx.send(());
                    }
                }
            }
            StreamEvent::Done { completion } => {
                c.done = true;
                c.terminal_step = Some(completion.finished_step);
                c.prefill_steps = Some(completion.prefill_steps);
                break;
            }
            StreamEvent::Cancelled { step, .. } => {
                c.terminal_step = Some(step);
                break;
            }
            StreamEvent::Queued { .. } => {}
        }
    }
    c
}

/// Run one throwaway request through a gateway so lazy XLA compilation is
/// out of the way before anything is timed.
fn warm(gw: &Gateway) -> Result<()> {
    let t = gw
        .submit(vec![2, 3], 2, SamplingParams::greedy(), None)
        .map_err(|e| anyhow::anyhow!("warm-up submit: {e}"))?;
    t.stream.wait()?;
    Ok(())
}

fn bench_streaming_vs_wave() -> Result<Json> {
    // Streaming run: open-loop submission through the gateway.
    let gw = Gateway::spawn("stream", gw_config(), EngineSpec::dense(ARTIFACTS, PRESET, BATCH_SLOTS, SEED))?;
    warm(&gw)?; // the serve_all side below gets the same treatment
    let t0 = Instant::now();
    let mut collectors = Vec::new();
    for id in 0..N_REQUESTS {
        let ticket = gw
            .submit(vec![2, 3], trace_max_new(id), SamplingParams::greedy(), None)
            .map_err(|e| anyhow::anyhow!("submit: {e}"))?;
        let stream = ticket.stream;
        collectors.push(thread::spawn(move || collect(stream, t0)));
        thread::sleep(Duration::from_micros(500));
    }
    let collected: Vec<Collected> =
        collectors.into_iter().map(|h| h.join().expect("collector")).collect();
    // Client-side window: t0 (first submit) → last terminal event received.
    // The gateway's own ServeMetrics span its whole lifetime (warm-up
    // request, lazy XLA compile, idle waits), which would bias the
    // throughput comparison against streaming — so the streaming side is
    // measured from the consumer's clock, like a client would.
    let stream_wall_s = t0.elapsed().as_secs_f64();
    let streamed_tokens: usize = collected.iter().map(|c| c.generated).sum();
    gw.join()?; // metrics span the worker lifetime (warm-up incl.) — not comparable

    let mut first_tokens: Vec<f64> = collected.iter().filter_map(|c| c.first_token_s).collect();
    first_tokens.sort_by(f64::total_cmp);

    // Wave-end run: the same trace through the blocking library call on a
    // fresh runtime; every token is delivered when serve_all returns.
    let rt = Runtime::new(ARTIFACTS)?;
    let params = clover::coordinator::ops::init_params(&rt, PRESET, SEED)?;
    let engine = clover::serve::Engine::new(
        &rt,
        PRESET,
        &format!("decode_b{BATCH_SLOTS}"),
        params,
    )?;
    let now = Instant::now();
    let reqs: Vec<clover::serve::Request> = (0..N_REQUESTS)
        .map(|id| clover::serve::Request::greedy(id, vec![2, 3], trace_max_new(id), now))
        .collect();
    let policy = clover::serve::BatchPolicy {
        max_batch: BATCH_SLOTS,
        max_wait: Duration::from_millis(1),
    };
    engine.serve_all(reqs.clone(), policy.clone())?; // warm the executable
    let t1 = Instant::now();
    let (_, wave_metrics) = engine.serve_all(reqs, policy)?;
    let wave_delivery_s = t1.elapsed().as_secs_f64();

    let earlier = first_tokens.iter().filter(|&&t| t < wave_delivery_s).count();
    println!(
        "streaming  : first token p50 {:.4}s / max {:.4}s vs serve_all delivery {:.4}s ({} of {} earlier)",
        clover::serve::engine::percentile(&first_tokens, 0.5),
        first_tokens.last().copied().unwrap_or(0.0),
        wave_delivery_s,
        earlier,
        first_tokens.len(),
    );

    let mut o = BTreeMap::new();
    o.insert("requests".to_string(), Json::Num(N_REQUESTS as f64));
    o.insert(
        "streaming_first_token_p50_s".to_string(),
        Json::Num(clover::serve::engine::percentile(&first_tokens, 0.5)),
    );
    o.insert(
        "streaming_first_token_max_s".to_string(),
        Json::Num(first_tokens.last().copied().unwrap_or(0.0)),
    );
    o.insert("serve_all_delivery_s".to_string(), Json::Num(wave_delivery_s));
    o.insert(
        "first_token_earlier_frac".to_string(),
        Json::Num(earlier as f64 / first_tokens.len().max(1) as f64),
    );
    // Streaming throughput over the client-observed window; the warm-up
    // request is excluded (it ran before t0 and has no collector).
    o.insert(
        "streaming_tokens_per_s".to_string(),
        Json::Num(if stream_wall_s > 0.0 { streamed_tokens as f64 / stream_wall_s } else { 0.0 }),
    );
    o.insert("streaming_wall_s".to_string(), Json::Num(stream_wall_s));
    o.insert("serve_all_tokens_per_s".to_string(), Json::Num(wave_metrics.tokens_per_s()));
    o.insert("serve_all_ttft_p50_s".to_string(), Json::Num(wave_metrics.ttft_p50_s));
    o.insert(
        "streaming_completed".to_string(),
        Json::Num(collected.iter().filter(|c| c.done).count() as f64),
    );
    Ok(Json::Obj(o))
}

fn bench_cancel_reclaim() -> Result<Json> {
    let gw = Gateway::spawn("cancel", gw_config(), EngineSpec::dense(ARTIFACTS, PRESET, BATCH_SLOTS, SEED))?;
    warm(&gw)?; // keep t0-relative fields free of one-time XLA compile cost
    let t0 = Instant::now();
    // Fill every lane with long requests, then queue one waiter.  The
    // victim gets the longest budget so it is still decoding when its
    // first token comes back and the cancel fires.
    let (notify_tx, notify_rx) = std::sync::mpsc::channel::<()>();
    let mut collectors = Vec::new();
    let mut victim_cancel = None;
    let (mut victim_id, mut waiter_id) = (0u64, 0u64);
    for i in 0..=BATCH_SLOTS {
        let max_new = if i == 3 { 40 } else { 24 };
        let ticket = gw
            .submit(vec![2, 3], max_new, SamplingParams::greedy(), None)
            .map_err(|e| anyhow::anyhow!("submit: {e}"))?;
        if i == 3 {
            victim_id = ticket.id;
        }
        if i == BATCH_SLOTS {
            waiter_id = ticket.id; // the 9th request: queues behind 8 full lanes
        }
        let stream = ticket.stream;
        if i == 3 {
            victim_cancel = Some(ticket.cancel.clone());
            let tx = notify_tx.clone();
            collectors.push(thread::spawn(move || collect_notify(stream, t0, Some(tx))));
        } else {
            collectors.push(thread::spawn(move || collect(stream, t0)));
        }
    }
    // Cancel the moment the victim's first token streams back: it is
    // provably mid-decode with ~39 tokens of budget left.
    notify_rx
        .recv_timeout(Duration::from_secs(30))
        .map_err(|_| anyhow::anyhow!("victim never produced a token"))?;
    let cancel_fired_s = t0.elapsed().as_secs_f64();
    victim_cancel.expect("victim ticket").cancel();

    let collected: Vec<Collected> =
        collectors.into_iter().map(|h| h.join().expect("collector")).collect();
    let metrics = gw.join()?;

    let victim = collected.iter().find(|c| c.id == victim_id).expect("victim");
    let waiter = collected.iter().find(|c| c.id == waiter_id).expect("waiter");
    let cancel_step = victim.terminal_step.unwrap_or(0);
    let waiter_step = waiter.started_step.unwrap_or(usize::MAX);
    let reclaim_steps = waiter_step.saturating_sub(cancel_step);
    println!(
        "cancel     : victim freed lane at step {cancel_step}, waiter admitted at step {waiter_step} \
         (reclaimed in {reclaim_steps} decode steps) | {} cancelled / {} completed",
        metrics.cancelled, metrics.completed,
    );

    let mut o = BTreeMap::new();
    o.insert("victim_cancelled".to_string(), Json::Bool(!victim.done));
    o.insert("victim_tokens_streamed".to_string(), Json::Num(victim.generated as f64));
    o.insert("cancel_fired_s".to_string(), Json::Num(cancel_fired_s));
    o.insert("cancel_step".to_string(), Json::Num(cancel_step as f64));
    o.insert("waiter_started_step".to_string(), Json::Num(waiter_step as f64));
    o.insert("reclaim_steps".to_string(), Json::Num(reclaim_steps as f64));
    o.insert("within_one_step".to_string(), Json::Bool(reclaim_steps <= 1));
    o.insert("waiter_first_token_s".to_string(), Json::Num(waiter.first_token_s.unwrap_or(0.0)));
    o.insert("cancelled".to_string(), Json::Num(metrics.cancelled as f64));
    o.insert("completed".to_string(), Json::Num(metrics.completed as f64));
    Ok(Json::Obj(o))
}

fn bench_router() -> Result<Json> {
    use clover::server::Router;
    // Cheapest-KV engine first: ties route toward the front.
    let router = Router::new(vec![
        Gateway::spawn("r4", gw_config(), EngineSpec::pruned(ARTIFACTS, PRESET, BATCH_SLOTS, SEED, 0.75))?,
        Gateway::spawn("r8", gw_config(), EngineSpec::pruned(ARTIFACTS, PRESET, BATCH_SLOTS, SEED, 0.5))?,
        Gateway::spawn("dense", gw_config(), EngineSpec::dense(ARTIFACTS, PRESET, BATCH_SLOTS, SEED))?,
    ])?;
    // Warm every engine so routing shares reflect scheduling, not which
    // gateway happened to pay its lazy XLA compile first.
    for g in router.gateways() {
        warm(g)?;
    }
    let t0 = Instant::now();
    let n = 3 * N_REQUESTS;
    let mut counts = vec![0usize; router.gateways().len()];
    let mut collectors = Vec::new();
    for id in 0..n {
        let (idx, ticket) = router
            .submit(vec![2, 3], trace_max_new(id), SamplingParams::greedy(), None)
            .map_err(|e| anyhow::anyhow!("submit: {e}"))?;
        counts[idx] += 1;
        let stream = ticket.stream;
        collectors.push(thread::spawn(move || collect(stream, t0)));
        thread::sleep(Duration::from_micros(500));
    }
    let done = collectors
        .into_iter()
        .map(|h| h.join().expect("collector"))
        .filter(|c| c.done)
        .count();
    let wall_s = t0.elapsed().as_secs_f64();
    let names: Vec<(String, usize)> = router
        .gateways()
        .iter()
        .map(|g| (g.name().to_string(), g.rank()))
        .collect();
    let metrics = router.join()?;

    let mut engines = Vec::new();
    for (((name, rank), routed), (_, m)) in names.iter().zip(&counts).zip(&metrics) {
        println!(
            "router     : {name:<6} rank {rank:>2} | {routed:>3}/{n} requests | {:>6.1} tok/s | peak KV {}",
            m.tokens_per_s(),
            human_bytes(m.kv_peak_bytes),
        );
        let mut o = BTreeMap::new();
        o.insert("name".to_string(), Json::Str(name.clone()));
        o.insert("rank".to_string(), Json::Num(*rank as f64));
        o.insert("share".to_string(), Json::Num(*routed as f64 / n as f64));
        o.insert("requests".to_string(), Json::Num(*routed as f64));
        o.insert("tokens_per_s".to_string(), Json::Num(m.tokens_per_s()));
        o.insert("decode_steps".to_string(), Json::Num(m.decode_steps as f64));
        o.insert("kv_peak_bytes".to_string(), Json::Num(m.kv_peak_bytes as f64));
        o.insert("ttft_p50_s".to_string(), Json::Num(m.ttft_p50_s));
        engines.push(Json::Obj(o));
    }
    let mut o = BTreeMap::new();
    o.insert("requests".to_string(), Json::Num(n as f64));
    o.insert("completed".to_string(), Json::Num(done as f64));
    o.insert("wall_s".to_string(), Json::Num(wall_s));
    o.insert("engines".to_string(), Json::Arr(engines));
    Ok(Json::Obj(o))
}

/// Gateway streaming over the stub backend: chunked 48-token prompts,
/// tokens streamed as sampled.  Runs with or without PJRT, so the bench
/// artifact always carries real serving numbers.
fn bench_stub_streaming() -> Result<Json> {
    let spec = StubSpec {
        max_positions: 128,
        batch_slots: BATCH_SLOTS,
        step_delay: Duration::from_millis(1),
        ..Default::default()
    };
    let prompt_len = 48usize;
    let gw = Gateway::spawn("stub-stream", gw_config(), EngineSpec::stub(spec))?;
    let t0 = Instant::now();
    let mut collectors = Vec::new();
    for id in 0..N_REQUESTS {
        let ticket = gw
            .submit(
                (0..prompt_len as i32).map(|i| i % 32).collect(),
                trace_max_new(id),
                SamplingParams::greedy(),
                None,
            )
            .map_err(|e| anyhow::anyhow!("submit: {e}"))?;
        let stream = ticket.stream;
        collectors.push(thread::spawn(move || collect(stream, t0)));
        thread::sleep(Duration::from_micros(500));
    }
    let collected: Vec<Collected> =
        collectors.into_iter().map(|h| h.join().expect("collector")).collect();
    let wall_s = t0.elapsed().as_secs_f64();
    let m = gw.join()?;
    let mut first: Vec<f64> = collected.iter().filter_map(|c| c.first_token_s).collect();
    first.sort_by(f64::total_cmp);
    let prefill_steps: Vec<usize> = collected.iter().filter_map(|c| c.prefill_steps).collect();
    let mean_prefill =
        prefill_steps.iter().sum::<usize>() as f64 / prefill_steps.len().max(1) as f64;
    println!(
        "stub stream: {} done | {prompt_len}-token prompts prefilled in {mean_prefill:.1} steps \
         | first token p50 {:.4}s | {} fused steps ({} slab tokens)",
        collected.iter().filter(|c| c.done).count(),
        clover::serve::engine::percentile(&first, 0.5),
        m.decode_steps,
        m.slab_tokens,
    );
    let mut o = BTreeMap::new();
    o.insert("requests".to_string(), Json::Num(N_REQUESTS as f64));
    o.insert("prompt_tokens".to_string(), Json::Num(prompt_len as f64));
    o.insert(
        "completed".to_string(),
        Json::Num(collected.iter().filter(|c| c.done).count() as f64),
    );
    o.insert("mean_prefill_steps".to_string(), Json::Num(mean_prefill));
    o.insert(
        "first_token_p50_s".to_string(),
        Json::Num(clover::serve::engine::percentile(&first, 0.5)),
    );
    o.insert("decode_steps".to_string(), Json::Num(m.decode_steps as f64));
    o.insert("slab_tokens".to_string(), Json::Num(m.slab_tokens as f64));
    o.insert("wall_s".to_string(), Json::Num(wall_s));
    Ok(Json::Obj(o))
}

fn main() -> Result<()> {
    println!("== perf_server ==");
    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("perf_server".to_string()));
    root.insert("preset".to_string(), Json::Str(PRESET.to_string()));

    // Stub-backed streaming runs everywhere — the artifact always carries
    // real serving numbers, PJRT or not.
    root.insert("stub_streaming".to_string(), bench_stub_streaming()?);

    // No live backend (vendored xla stub) or no artifacts: record the skip
    // instead of failing, so the artifact upload always has something.
    if let Err(e) = Runtime::new(ARTIFACTS) {
        println!("runtime unavailable, skipping the artifact-backed experiments\n  ({e:#})");
        root.insert("skipped".to_string(), Json::Bool(true));
        root.insert("reason".to_string(), Json::Str(format!("{e:#}")));
        std::fs::write("BENCH_server.json", json::to_string(&Json::Obj(root)))?;
        return Ok(());
    }
    root.insert("skipped".to_string(), Json::Bool(false));

    root.insert("streaming".to_string(), bench_streaming_vs_wave()?);
    root.insert("cancel".to_string(), bench_cancel_reclaim()?);
    root.insert("router".to_string(), bench_router()?);

    std::fs::write("BENCH_server.json", json::to_string(&Json::Obj(root)))?;
    println!("wrote BENCH_server.json");
    Ok(())
}
