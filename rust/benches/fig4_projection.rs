//! Bench: regenerate the paper's Fig 4 on this testbed.
//! `cargo bench --bench fig4_projection` (add `-- --full` for paper-scale budgets).
use clover::coordinator::experiments::{self, ExpOpts};
use clover::runtime::Runtime;
use clover::util::Stopwatch;

fn main() -> anyhow::Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let sw = Stopwatch::new();
    let rt = Runtime::new("artifacts")?;
    let opts = ExpOpts { preset: "tiny".into(), quick: !full, seed: 42 };
    let table = experiments::fig4(&rt, &opts)?;
    table.emit("fig4_projection")?;
    println!("[fig4_projection] total {:.1}s", sw.elapsed_s());
    Ok(())
}
