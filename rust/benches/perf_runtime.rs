//! Perf bench: the PJRT execute hot path.
//!
//! Measures per-call wall time and the marshal/execute split (from
//! `Runtime::stats`) for the forward, nll, train-step, and decode
//! programs — the numbers the §Perf iteration log in EXPERIMENTS.md
//! tracks before/after each optimization.

use anyhow::Result;
use clover::coordinator::ops;
use clover::runtime::Runtime;
use clover::tensor::{Tensor, TensorI, Value};
use clover::util::rng::Rng;

fn main() -> Result<()> {
    let rt = Runtime::new("artifacts")?;
    let preset = "tiny";
    let entry = rt.manifest().config(preset)?.clone();
    let (b, t) = (entry.dim("train_batch")?, entry.dim("seq_len")?);
    let dense = ops::init_params(&rt, preset, 1)?;
    let mut rng = Rng::new(0);
    println!("== perf_runtime ({preset}) ==");

    let toks = |rng: &mut Rng| -> TensorI {
        TensorI::new(vec![b, t], (0..b * t).map(|_| rng.below(256) as i32).collect())
    };

    // fwd
    {
        let mut args: Vec<Value> = dense.flat().iter().map(|&x| Value::F32(x.clone())).collect();
        args.push(Value::I32(toks(&mut rng)));
        rt.run(preset, "fwd", &args)?; // compile+warm
        rt.reset_stats();
        let n = 20;
        let t0 = std::time::Instant::now();
        for _ in 0..n {
            std::hint::black_box(rt.run(preset, "fwd", &args)?);
        }
        let dt = t0.elapsed().as_secs_f64();
        let st = rt.stats();
        println!(
            "fwd        : {:7.2} ms/call  (execute {:5.1}%  marshal {:5.1}%)  {:.0} tok/s",
            dt / n as f64 * 1e3,
            100.0 * st.execute_s / dt, 100.0 * st.marshal_s / dt,
            (n * b * t) as f64 / dt
        );
    }

    // nll
    {
        let mut args: Vec<Value> = dense.flat().iter().map(|&x| Value::F32(x.clone())).collect();
        args.push(Value::I32(toks(&mut rng)));
        args.push(Value::I32(toks(&mut rng)));
        rt.run(preset, "nll", &args)?;
        rt.reset_stats();
        let n = 20;
        let t0 = std::time::Instant::now();
        for _ in 0..n {
            std::hint::black_box(rt.run(preset, "nll", &args)?);
        }
        let dt = t0.elapsed().as_secs_f64();
        println!("nll        : {:7.2} ms/call", dt / n as f64 * 1e3);
    }

    // train_full via the trainer (includes state write-back)
    {
        use clover::coordinator::trainer::{train_step, TrainState};
        use std::collections::BTreeMap;
        let mut state = TrainState::new(vec![dense.clone()]);
        let mut batch = BTreeMap::new();
        batch.insert("inputs".to_string(), Value::I32(toks(&mut rng)));
        batch.insert("targets".to_string(), Value::I32(toks(&mut rng)));
        train_step(&rt, preset, "train_full", &mut state, &batch, 1e-3)?;
        rt.reset_stats();
        let n = 10;
        let t0 = std::time::Instant::now();
        for _ in 0..n {
            std::hint::black_box(train_step(&rt, preset, "train_full", &mut state, &batch, 1e-3)?);
        }
        let dt = t0.elapsed().as_secs_f64();
        let st = rt.stats();
        println!(
            "train_full : {:7.2} ms/step  (execute {:5.1}%  marshal {:5.1}%)  {:.0} tok/s",
            dt / n as f64 * 1e3,
            100.0 * st.execute_s / dt, 100.0 * st.marshal_s / dt,
            (n * b * t) as f64 / dt
        );
    }

    // decode (dense vs factorized at half rank)
    for (label, prog, params) in [
        ("decode d=16", "decode_b8".to_string(), dense.clone()),
        ("decode r=8 ", {
            let r = 8;
            format!("decode_fac_r{r}_b8")
        }, ops::prune_to_ratio(&entry, &dense, 0.5, "clover")?.0),
    ] {
        let sig = rt.manifest().config(preset)?.program(&prog)?.clone();
        let cache_shape = sig.inputs.iter().find(|a| a.name.ends_with("_cache")).unwrap()
            .shape.clone();
        let toks = Value::I32(TensorI::new(vec![8], vec![1; 8]));
        let poss = Value::I32(TensorI::zeros(&[8]));
        let mut args: Vec<Value> = params.flat().iter().map(|&x| Value::F32(x.clone())).collect();
        args.push(Value::F32(Tensor::zeros(&cache_shape)));
        args.push(Value::F32(Tensor::zeros(&cache_shape)));
        args.push(toks.clone());
        args.push(poss.clone());
        rt.run(preset, &prog, &args)?;
        rt.reset_stats();
        let n = 30;
        let t0 = std::time::Instant::now();
        for _ in 0..n {
            std::hint::black_box(rt.run(preset, &prog, &args)?);
        }
        let dt = t0.elapsed().as_secs_f64();
        let st = rt.stats();
        println!(
            "{label}: {:7.2} ms/step  (execute {:5.1}%  marshal {:5.1}%)  {:.0} tok/s batched",
            dt / n as f64 * 1e3,
            100.0 * st.execute_s / dt, 100.0 * st.marshal_s / dt,
            (n * 8) as f64 / dt
        );
        // §Perf optimization 1: params marshalled once (run_prepared).
        let param_values: Vec<Value> =
            params.flat().iter().map(|&x| Value::F32(x.clone())).collect();
        let prepared = rt.prepare(&param_values.iter().collect::<Vec<_>>())?;
        let rest = vec![
            Value::F32(Tensor::zeros(&cache_shape)),
            Value::F32(Tensor::zeros(&cache_shape)),
            toks.clone(),
            poss.clone(),
        ];
        rt.run_prepared(preset, &prog, &prepared, &rest)?;
        rt.reset_stats();
        let t0 = std::time::Instant::now();
        for _ in 0..n {
            std::hint::black_box(rt.run_prepared(preset, &prog, &prepared, &rest)?);
        }
        let dt2 = t0.elapsed().as_secs_f64();
        let st = rt.stats();
        println!(
            "{label} (prepared params): {:7.2} ms/step  (execute {:5.1}%  marshal {:5.1}%)  {:+.1}% vs baseline",
            dt2 / n as f64 * 1e3,
            100.0 * st.execute_s / dt2, 100.0 * st.marshal_s / dt2,
            100.0 * (dt2 - dt) / dt
        );
        // §Perf optimization 2: caches carried literal-side (DecodeSession)
        // — the per-step conversions shrink to tokens/positions + logits.
        let mut dec = clover::runtime::DecodeSession::new(&rt, preset, &prog, &param_values)?;
        let step_args = vec![toks, poss];
        dec.step(&step_args)?;
        rt.reset_stats();
        let t0 = std::time::Instant::now();
        for _ in 0..n {
            std::hint::black_box(dec.step(&step_args)?);
        }
        let dt3 = t0.elapsed().as_secs_f64();
        let st = rt.stats();
        println!(
            "{label} (decode session) : {:7.2} ms/step  (execute {:5.1}%  marshal {:5.1}%)  {:+.1}% vs baseline",
            dt3 / n as f64 * 1e3,
            100.0 * st.execute_s / dt3, 100.0 * st.marshal_s / dt3,
            100.0 * (dt3 - dt) / dt
        );
    }
    Ok(())
}
