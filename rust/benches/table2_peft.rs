//! Bench: regenerate Table 2 / Figs 5–6 (one shared training sweep).
use clover::coordinator::experiments::{self, ExpOpts};
use clover::runtime::Runtime;
use clover::util::Stopwatch;

fn main() -> anyhow::Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let sw = Stopwatch::new();
    let rt = Runtime::new("artifacts")?;
    let opts = ExpOpts { preset: "tiny".into(), quick: !full, seed: 42 };
    let (table, outcomes) = experiments::table2(&rt, &opts)?;
    table.emit("table2")?;
    experiments::fig5_from(&outcomes).emit("fig5")?;
    experiments::fig6_from(&outcomes).emit("fig6")?;
    println!("[table2_peft] total {:.1}s", sw.elapsed_s());
    Ok(())
}
