//! Bench: regenerate the paper's Fig 1c on this testbed.
//! `cargo bench --bench fig1c_ppl_curve` (add `-- --full` for paper-scale budgets).
use clover::coordinator::experiments::{self, ExpOpts};
use clover::runtime::Runtime;
use clover::util::Stopwatch;

fn main() -> anyhow::Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let sw = Stopwatch::new();
    let rt = Runtime::new("artifacts")?;
    let opts = ExpOpts { preset: "tiny".into(), quick: !full, seed: 42 };
    let table = experiments::fig1c(&rt, &opts)?;
    table.emit("fig1c_ppl_curve")?;
    println!("[fig1c_ppl_curve] total {:.1}s", sw.elapsed_s());
    Ok(())
}
