//! Perf bench: the linalg substrate on CLOVER-shaped problems.
//!
//! Times matmul / QR / Jacobi SVD at the sizes the checkpoint transform
//! actually hits (D×d thin factors, d×d cores, D×D analysis matrices) and
//! the full per-head `factorize_pair`.  No criterion in the vendored set —
//! a min-of-N harness with warmup is used instead.

use clover::clover::transform::factorize_pair;
use clover::linalg::{matmul, matmul_nt, qr::qr_thin, svd::svd};
use clover::tensor::Tensor;
use clover::util::rng::Rng;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) {
    // warmup
    f();
    let mut best = f64::INFINITY;
    let mut total = 0.0;
    for _ in 0..iters {
        let t0 = std::time::Instant::now();
        f();
        let dt = t0.elapsed().as_secs_f64();
        best = best.min(dt);
        total += dt;
    }
    println!(
        "{name:<40} min {:>9.3} ms   mean {:>9.3} ms",
        best * 1e3,
        total / iters as f64 * 1e3
    );
}

fn main() {
    let mut rng = Rng::new(0);
    println!("== perf_linalg ==");

    for (m, k, n) in [(64, 64, 64), (256, 256, 256), (256, 32, 256)] {
        let a = Tensor::new(vec![m, k], rng.normal_vec(m * k, 1.0));
        let b = Tensor::new(vec![k, n], rng.normal_vec(k * n, 1.0));
        bench(&format!("matmul {m}x{k}x{n}"), 10, || {
            std::hint::black_box(matmul(&a, &b));
        });
    }

    for (d, dh) in [(64, 16), (256, 32), (768, 64)] {
        let a = Tensor::new(vec![d, dh], rng.normal_vec(d * dh, 1.0));
        bench(&format!("qr_thin {d}x{dh}"), 10, || {
            std::hint::black_box(qr_thin(&a));
        });
    }

    for n in [16, 32, 64, 256] {
        let a = Tensor::new(vec![n, n], rng.normal_vec(n * n, 1.0));
        bench(&format!("jacobi svd {n}x{n}"), if n > 128 { 3 } else { 10 }, || {
            std::hint::black_box(svd(&a));
        });
    }

    for (d, dh) in [(64, 16), (256, 32), (768, 64)] {
        let a = Tensor::new(vec![d, dh], rng.normal_vec(d * dh, 1.0));
        let b = Tensor::new(vec![d, dh], rng.normal_vec(d * dh, 1.0));
        bench(&format!("factorize_pair D={d} d={dh} (per head)"), 5, || {
            std::hint::black_box(factorize_pair(&a, &b, dh));
        });
        bench(&format!("materialized SVD D={d} (naive baseline)"), 2, || {
            let w = matmul_nt(&a, &b);
            std::hint::black_box(svd(&w));
        });
    }
}
