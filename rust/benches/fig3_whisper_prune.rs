//! Bench: regenerate §4.4 / Fig 3 (whisper-like training-free pruning).
use clover::coordinator::experiments::{self, ExpOpts};
use clover::runtime::Runtime;
use clover::util::Stopwatch;

fn main() -> anyhow::Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let sw = Stopwatch::new();
    let rt = Runtime::new("artifacts")?;
    let opts = ExpOpts { preset: "tiny".into(), quick: !full, seed: 42 };
    experiments::fig3_whisper(&rt, &opts)?.emit("fig3_whisper_prune")?;
    println!("[fig3_whisper_prune] total {:.1}s", sw.elapsed_s());
    Ok(())
}
