//! Bench: regenerate the paper's Fig 1d on this testbed.
//! `cargo bench --bench fig1d_recovery` (add `-- --full` for paper-scale budgets).
use clover::coordinator::experiments::{self, ExpOpts};
use clover::runtime::Runtime;
use clover::util::Stopwatch;

fn main() -> anyhow::Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let sw = Stopwatch::new();
    let rt = Runtime::new("artifacts")?;
    let opts = ExpOpts { preset: "tiny".into(), quick: !full, seed: 42 };
    let table = experiments::fig1d(&rt, &opts)?;
    table.emit("fig1d_recovery")?;
    println!("[fig1d_recovery] total {:.1}s", sw.elapsed_s());
    Ok(())
}
