//! Perf bench: serving-layer components in isolation (batcher admission,
//! KV allocator churn) plus the end-to-end engine throughput at several
//! pruning ranks.

use anyhow::Result;
use clover::coordinator::ops;
use clover::runtime::Runtime;
use clover::serve::{BatchPolicy, Batcher, Engine, KvConfig, KvManager, Request};
use clover::util::human_bytes;
use std::time::{Duration, Instant};

fn main() -> Result<()> {
    println!("== perf_serve ==");

    // Batcher micro-bench: admission throughput.
    {
        let now = Instant::now();
        let n = 200_000;
        let t0 = Instant::now();
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, max_wait: Duration::ZERO });
        let mut admitted = 0usize;
        for i in 0..n {
            b.push(Request { id: i, prompt: vec![1], max_new: 1, arrived: now });
            if b.ready(now, false) {
                admitted += b.take_batch().len();
            }
        }
        admitted += b.take_batch().len();
        let dt = t0.elapsed().as_secs_f64();
        println!("batcher    : {:.1}M req/s (admitted {admitted})", n as f64 / dt / 1e6);
    }

    // KV allocator churn.
    {
        let cfg = KvConfig { n_layers: 4, n_heads: 8, rank: 16, max_positions: 128, batch_slots: 8 };
        let mut kv = KvManager::new(cfg);
        let n = 100_000;
        let t0 = Instant::now();
        for i in 0..n {
            let s = kv.allocate(i).unwrap();
            for _ in 0..8 {
                kv.advance(s).unwrap();
            }
            kv.free(s).unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        println!("kv manager : {:.2}M alloc-advance8-free/s", n as f64 / dt / 1e6);
    }

    // End-to-end engine at dense vs pruned ranks.
    let rt = Runtime::new("artifacts")?;
    let preset = "tiny";
    let entry = rt.manifest().config(preset)?.clone();
    let dense = ops::init_params(&rt, preset, 1)?;
    let now = Instant::now();
    let policy = BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(1) };
    let mk = || -> Vec<Request> {
        (0..8u64).map(|id| Request { id, prompt: vec![2, 3], max_new: 16, arrived: now }).collect()
    };
    let (_, m) = Engine::new(&rt, preset, "decode_b8", dense.clone())?.serve_all(mk(), policy.clone())?;
    println!("engine dense : {:6.1} tok/s  peak KV {}", m.tokens_per_s(),
             human_bytes(m.kv_peak_bytes));
    for ratio in [0.5, 0.75] {
        let (fac, r) = ops::prune_to_ratio(&entry, &dense, ratio, "clover")?;
        let engine = Engine::new(&rt, preset, &format!("decode_fac_r{r}_b8"), fac)?;
        let (_, m) = engine.serve_all(mk(), policy.clone())?;
        println!("engine r={r:<3}: {:6.1} tok/s  peak KV {}", m.tokens_per_s(),
                 human_bytes(m.kv_peak_bytes));
    }
    Ok(())
}
