//! Perf bench: serving-layer components in isolation (batcher admission,
//! KV allocator churn), the chunked-prefill step ladder, and the
//! end-to-end engine at several pruning ranks, run both ways — the old
//! batch-to-completion wave schedule vs the continuous-batching scheduler
//! — so the step/latency gap slot-level admission buys is measured, not
//! asserted.
//!
//! Emits `BENCH_serve.json` (see docs/BENCH_SCHEMAS.md):
//!
//! * `prefill` — TTFT-vs-chunk-width over the deterministic stub backend:
//!   the same 64-token-prompt trace served with the slab ladder capped at
//!   K=1 / K=8 / K=32, reporting prefill steps, total fused steps, and
//!   TTFT per cap.  Runs on every checkout (no PJRT needed) — these are
//!   the step counts the acceptance bar reads.
//! * `speculative` — acceptance-vs-draft-length sweep over a stub
//!   draft+verify pair (rank-4 draft, rank-8 target, same seed — a
//!   spectrum truncation).  Per draft length K ∈ {2, 4, 8}: acceptance
//!   rate, dense decode steps per generated token (the < 1.0 acceptance
//!   bar), draft steps, rollback tokens, and a bit-identity check against
//!   the vanilla greedy trace.  Always on (stub backend).
//! * `kv_codec` — lanes-at-fixed-memory vs page codec: the same request
//!   trace under one `--kv-memory-budget` served with the identity codec
//!   and with the factored codec at rank/2 and rank/4 budgets, the
//!   concurrent lane count *measured* through a step-hook census (not
//!   computed from config).  The acceptance bar (factored ≥ 2× identity
//!   lanes) reads this section.  Always on (stub backend).
//! * `layer_budgets` — accuracy-vs-layer-budget: greedy-token prefix
//!   agreement against the identity baseline across DepthKV-style
//!   per-layer budget profiles on a 2-layer stub; the full-rank profile
//!   must agree exactly (the factored codec at full budgets is a pure
//!   copy).  Always on (stub backend).
//! * `obs` — observability tap cost and fidelity: best-of-3 tokens/s
//!   with a `NoHook` vs a `TraceSink` tap (the <5% overhead bar), the
//!   span-reconstructed aggregates vs the engine's own `ServeMetrics`
//!   (exact counts, float-tolerance TTFT), and the gateway-registry
//!   counter agreement.  Also writes `BENCH_trace.json` (Chrome
//!   trace-event JSON) and `BENCH_metrics.json` (registry dump).  Always
//!   on (stub backend).
//! * `prefix_cache` — TTFT/tokens-per-s vs prefix-share ratio under a
//!   Zipf-head prompt mix on virtual time: each share served cache-on vs
//!   cache-off over an identical trace at a fixed `--kv-memory-budget`,
//!   plus a tight-budget row that forces mid-serve eviction.  The
//!   acceptance bar (cache-on beats cache-off at share ≥ 0.5, monotone
//!   TTFT, bit-identity to cold) reads this section.  Always on (stub
//!   backend).
//! * `fault_recovery` — goodput under injected faults, on virtual time:
//!   a transient-rate sweep (0 / 1% / 5%) under the retry policy on a
//!   manual `Clock` (the acceptance bars: zero lost requests at every
//!   rate, goodput at 1% ≥ 0.9× fault-free, bit-identical rows), a
//!   supervised-recovery drill (scheduled backend death, engine rebuilt
//!   and replayed losslessly — restarts read back from the registry),
//!   and a fleet-failover drill (a doomed engine's orphans re-homed to a
//!   sibling through `Router::fail_over`, breaker forced Open).  Always
//!   on (stub backend).
//! * `engines` — tokens/s, TTFT, p50/p99 latency, fused steps, KV peak
//!   bytes, marshal/execute split per engine×admission-mode, against the
//!   compiled artifacts.  Skipped (with `pjrt_skipped: true`) when no
//!   live backend or artifacts exist, so the artifact always uploads.

use anyhow::Result;
use clover::config::json::{self, Json};
use clover::coordinator::ops;
use clover::runtime::stub::StubSpec;
use clover::runtime::Runtime;
use clover::serve::{
    Admission, BatchPolicy, Batcher, CancelReason, Completion, Engine, KvCodecSpec, KvConfig,
    KvManager, Request, SamplingParams, SpecConfig, StepHook,
};
use clover::util::human_bytes;
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

const BATCH_SLOTS: usize = 8;
/// 2× the slot count, mixed lengths — the continuous-batching regime.
const N_REQUESTS: u64 = 16;
/// Prompt length for the chunked-prefill section (the acceptance bar's
/// 64-token prompt).
const PREFILL_PROMPT: usize = 64;

fn mk_requests(now: Instant) -> Vec<Request> {
    (0..N_REQUESTS)
        .map(|id| Request::greedy(id, vec![2, 3], 4 + (id as usize % 4) * 6, now))
        .collect()
}

fn policy() -> BatchPolicy {
    BatchPolicy { max_batch: BATCH_SLOTS, max_wait: Duration::from_millis(1) }
}

/// TTFT-vs-chunk-width on the stub backend: same trace, ladder capped at
/// each width.  Step counts are exact and deterministic; wall-clock TTFT
/// is the stub's, useful relatively (the ladder is the only variable).
fn bench_prefill_chunks() -> Result<Json> {
    let spec = StubSpec { max_positions: 128, batch_slots: BATCH_SLOTS, ..Default::default() };
    let ladder = spec.widths();
    let mk = |now: Instant| -> Vec<Request> {
        (0..BATCH_SLOTS as u64)
            .map(|id| {
                Request::greedy(
                    id,
                    (0..PREFILL_PROMPT as i32).map(|i| i % 32).collect(),
                    8,
                    now,
                )
            })
            .collect()
    };
    let mut rows = Vec::new();
    let mut k1_prefill_steps = 0usize;
    for cap in [1usize, 8, 32] {
        let engine = Engine::new_stub(spec.clone()).with_prefill_chunk(Some(cap));
        let now = Instant::now();
        let (completions, m) = engine.serve_all(mk(now), policy())?;
        let prefill_steps = completions.first().map_or(0, |c| c.prefill_steps);
        if cap == 1 {
            k1_prefill_steps = prefill_steps;
        }
        println!(
            "prefill K={cap:<2}: {prefill_steps:>3} prefill steps for a {PREFILL_PROMPT}-token prompt \
             | {:>3} fused steps total | ttft p50 {:.4}s | {:.0} tok/s  ({}x vs K=1)",
            m.decode_steps,
            m.ttft_p50_s,
            m.tokens_per_s(),
            if prefill_steps > 0 { k1_prefill_steps / prefill_steps } else { 0 },
        );
        let mut o = BTreeMap::new();
        o.insert("chunk".to_string(), Json::Num(cap as f64));
        // The widths this row's engine actually planned over (the cap
        // applied), not the spec's full ladder.
        o.insert(
            "ladder".to_string(),
            Json::Arr(engine.widths().iter().map(|&w| Json::Num(w as f64)).collect()),
        );
        o.insert("prefill_steps".to_string(), Json::Num(prefill_steps as f64));
        o.insert("decode_steps".to_string(), Json::Num(m.decode_steps as f64));
        o.insert("slab_tokens".to_string(), Json::Num(m.slab_tokens as f64));
        o.insert("ttft_p50_s".to_string(), Json::Num(m.ttft_p50_s));
        o.insert("ttft_p99_s".to_string(), Json::Num(m.ttft_p99_s));
        o.insert("tokens_per_s".to_string(), Json::Num(m.tokens_per_s()));
        o.insert(
            "prefill_step_reduction_vs_k1".to_string(),
            Json::Num(if prefill_steps > 0 {
                k1_prefill_steps as f64 / prefill_steps as f64
            } else {
                0.0
            }),
        );
        rows.push(Json::Obj(o));
    }
    let mut o = BTreeMap::new();
    o.insert("backend".to_string(), Json::Str("stub".to_string()));
    o.insert("prompt_tokens".to_string(), Json::Num(PREFILL_PROMPT as f64));
    o.insert("requests".to_string(), Json::Num(BATCH_SLOTS as f64));
    // All widths the stub exports; each row's own `ladder` is the capped
    // subset its engine planned over.
    o.insert(
        "ladder".to_string(),
        Json::Arr(ladder.iter().map(|&w| Json::Num(w as f64)).collect()),
    );
    o.insert("chunks".to_string(), Json::Arr(rows));
    Ok(Json::Obj(o))
}

/// Self-speculative decoding on the stub pair: a rank-4 draft proposing
/// for a rank-8 target with the same seed (a spectrum truncation — the
/// stub analogue of CLOVER pruning the model that verifies it).  Sweeps
/// draft length K, reporting acceptance and dense steps-per-token, and
/// asserts the bit-identity invariant against vanilla greedy decode.
fn bench_speculative() -> Result<Json> {
    const TARGET_RANK: usize = 8;
    const DRAFT_RANK: usize = 4;
    let target = StubSpec {
        n_layers: 1,
        n_heads: 2,
        rank: TARGET_RANK,
        vocab: 16,
        max_positions: 128,
        batch_slots: BATCH_SLOTS,
        ..Default::default()
    };
    let draft = StubSpec { rank: DRAFT_RANK, ..target.clone() };
    let mk = |now: Instant, speculative: bool| -> Vec<Request> {
        let sampling = if speculative {
            SamplingParams::speculative_greedy()
        } else {
            SamplingParams::greedy()
        };
        (0..BATCH_SLOTS as u64)
            .map(|id| Request {
                id,
                prompt: (0..16).map(|i| (3 + i * 5 + id as i32) % 16).collect(),
                max_new: 32,
                arrived: now,
                sampling: sampling.clone(),
            })
            .collect()
    };
    // Per-request dense steps per generated token: each request's own
    // fused target steps (prefill excluded, draft steps excluded — those
    // run on the cheap engine) over its own generated tokens.  Vanilla
    // decode sits at ~1.0 by construction (one dense step per token, the
    // prefill-boundary token excepted); speculation pushes it well below.
    const PROMPT: usize = 16;
    let dense_spt_of = |completions: &[clover::serve::Completion]| -> f64 {
        let steps: usize = completions.iter().map(|c| c.steps - c.prefill_steps).sum();
        let generated: usize = completions.iter().map(|c| c.tokens.len() - PROMPT).sum();
        steps as f64 / generated.max(1) as f64
    };
    let now = Instant::now();
    let vanilla = Engine::new_stub(target.clone());
    let (vanilla_c, _vanilla_m) = vanilla.serve_all(mk(now, false), policy())?;
    let vanilla_spt = dense_spt_of(&vanilla_c);

    let mut rows = Vec::new();
    for draft_len in [2usize, 4, 8] {
        let engine = Engine::new_stub(target.clone())
            .with_speculative_stub(draft.clone(), SpecConfig { draft_len, adaptive: false })?;
        let (c, m) = engine.serve_all(mk(now, true), policy())?;
        let bit_identical =
            c.iter().zip(&vanilla_c).all(|(a, b)| a.tokens == b.tokens);
        let dense_spt = dense_spt_of(&c);
        println!(
            "speculative K={draft_len}: acceptance {:5.1}% | {:.2} dense steps/token (vanilla {vanilla_spt:.2}) \
             | {:3} verify rounds | {:3} draft steps | {:3} rolled back | bit-identical {bit_identical}",
            100.0 * m.acceptance_rate(),
            dense_spt,
            m.spec_rounds,
            m.draft_steps,
            m.rollback_tokens,
        );
        let mut o = BTreeMap::new();
        o.insert("draft_len".to_string(), Json::Num(draft_len as f64));
        o.insert("acceptance_rate".to_string(), Json::Num(m.acceptance_rate()));
        o.insert("dense_steps_per_token".to_string(), Json::Num(dense_spt));
        o.insert("decode_steps".to_string(), Json::Num(m.decode_steps as f64));
        o.insert("draft_steps".to_string(), Json::Num(m.draft_steps as f64));
        o.insert("spec_rounds".to_string(), Json::Num(m.spec_rounds as f64));
        o.insert("drafted_tokens".to_string(), Json::Num(m.drafted_tokens as f64));
        o.insert(
            "accepted_draft_tokens".to_string(),
            Json::Num(m.accepted_draft_tokens as f64),
        );
        o.insert("rollback_tokens".to_string(), Json::Num(m.rollback_tokens as f64));
        o.insert("generated_tokens".to_string(), Json::Num(m.generated_tokens as f64));
        o.insert("tokens_per_s".to_string(), Json::Num(m.tokens_per_s()));
        o.insert("bit_identical_to_vanilla".to_string(), Json::Bool(bit_identical));
        rows.push(Json::Obj(o));
    }
    let mut o = BTreeMap::new();
    o.insert("backend".to_string(), Json::Str("stub".to_string()));
    o.insert("target_rank".to_string(), Json::Num(TARGET_RANK as f64));
    o.insert("draft_rank".to_string(), Json::Num(DRAFT_RANK as f64));
    o.insert("requests".to_string(), Json::Num(BATCH_SLOTS as f64));
    o.insert("max_new".to_string(), Json::Num(32.0));
    o.insert("vanilla_steps_per_token".to_string(), Json::Num(vanilla_spt));
    o.insert("sweep".to_string(), Json::Arr(rows));
    Ok(Json::Obj(o))
}

/// Counts concurrently live lanes through the step hook: the lane count
/// the fixed memory budget actually admitted, as observed at the
/// scheduler boundary — not derived from the codec's page size.
#[derive(Default)]
struct LaneCensus {
    live: usize,
    max_live: usize,
}

impl StepHook for LaneCensus {
    fn on_started(&mut self, _id: u64, _lane: usize, _step: usize) {
        self.live += 1;
        self.max_live = self.max_live.max(self.live);
    }

    fn on_done(&mut self, _completion: &Completion) {
        self.live -= 1;
    }

    fn on_cancelled(&mut self, _id: u64, _t: Vec<i32>, _r: CancelReason, _s: usize) {
        self.live -= 1;
    }
}

/// Lanes-at-fixed-memory vs page codec.  One 1-layer rank-8 stub, one
/// fixed KV byte budget sized to 4 identity pages, requests whose
/// worst-case row is exactly one page: the identity codec admits 4
/// concurrent lanes, the factored codec at rank/2 admits 8, at rank/4 all
/// 16 — the compressed pages *are* the extra lanes.  Lane counts come
/// from a [`LaneCensus`] hook, throughput from the same runs.
fn bench_kv_codecs() -> Result<Json> {
    const RANK: usize = 8;
    const SLOTS: usize = 16;
    let spec = StubSpec {
        n_layers: 1,
        n_heads: 2,
        rank: RANK,
        vocab: 16,
        max_positions: 128,
        batch_slots: SLOTS,
        ..Default::default()
    };
    // Prompt 8 + max_new 8 = one 16-token page worst case per request.
    let mk = |now: Instant| -> Vec<Request> {
        (0..SLOTS as u64)
            .map(|id| {
                Request::greedy(id, (0..8).map(|p| (id as i32 + p) % 16).collect(), 8, now)
            })
            .collect()
    };
    let pol = BatchPolicy { max_batch: SLOTS, max_wait: Duration::from_millis(1) };
    // Budget = 4 identity pages, so the identity codec admits exactly 4
    // concurrent one-page lanes.
    let probe = Engine::new_stub(spec.clone());
    let budget = 4 * probe.kv_config().bytes_per_page();

    let codecs = [
        ("identity", KvCodecSpec::Identity),
        ("factored_r4", KvCodecSpec::Factored { layer_budgets: Some(vec![RANK / 2]) }),
        ("factored_r2", KvCodecSpec::Factored { layer_budgets: Some(vec![RANK / 4]) }),
    ];
    let mut rows = Vec::new();
    let mut identity_lanes = 0usize;
    for (name, codec) in codecs {
        let engine = Engine::new_stub(spec.clone())
            .with_kv_codec(codec)?
            .with_kv_memory_budget(Some(budget));
        let cfg = engine.kv_config();
        let bytes_per_token = engine.kv_bytes_per_token_total();
        let bytes_per_page = cfg.bytes_per_page();
        let stored_ranks = cfg.stored_ranks();
        let mut census = LaneCensus::default();
        let now = Instant::now();
        let (completions, m) =
            engine.serve_hooked(mk(now), pol.clone(), Admission::Continuous, &mut census)?;
        if name == "identity" {
            identity_lanes = census.max_live;
        }
        println!(
            "kv codec {name:<12}: {:>2} concurrent lanes under a {} budget ({:.1}x identity) \
             | {:>4} B/page | {:>3} B/token | {:.0} tok/s | {} completed",
            census.max_live,
            human_bytes(budget),
            census.max_live as f64 / identity_lanes.max(1) as f64,
            bytes_per_page,
            bytes_per_token,
            m.tokens_per_s(),
            completions.len(),
        );
        let mut o = BTreeMap::new();
        o.insert("codec".to_string(), Json::Str(name.to_string()));
        o.insert(
            "layer_budgets".to_string(),
            Json::Arr(stored_ranks.iter().map(|&r| Json::Num(r as f64)).collect()),
        );
        o.insert("bytes_per_token".to_string(), Json::Num(bytes_per_token as f64));
        o.insert("bytes_per_page".to_string(), Json::Num(bytes_per_page as f64));
        o.insert("max_concurrent_lanes".to_string(), Json::Num(census.max_live as f64));
        o.insert(
            "lanes_vs_identity".to_string(),
            Json::Num(census.max_live as f64 / identity_lanes.max(1) as f64),
        );
        o.insert("completed".to_string(), Json::Num(m.completed as f64));
        o.insert("tokens_per_s".to_string(), Json::Num(m.tokens_per_s()));
        o.insert("kv_peak_bytes".to_string(), Json::Num(m.kv_peak_bytes as f64));
        o.insert("kv_freed_bytes".to_string(), Json::Num(m.kv_freed_bytes as f64));
        rows.push(Json::Obj(o));
    }
    let mut o = BTreeMap::new();
    o.insert("backend".to_string(), Json::Str("stub".to_string()));
    o.insert("rank".to_string(), Json::Num(RANK as f64));
    o.insert("requests".to_string(), Json::Num(SLOTS as f64));
    o.insert("memory_budget_bytes".to_string(), Json::Num(budget as f64));
    o.insert("codecs".to_string(), Json::Arr(rows));
    Ok(Json::Obj(o))
}

/// Accuracy-vs-layer-budget: the same greedy trace served through the
/// factored codec at progressively tighter DepthKV-style per-layer
/// budgets on a 2-layer stub, scored as mean longest-common-prefix
/// agreement against the identity baseline.  Full budgets are a pure
/// copy, so that profile must agree exactly (1.0); tighter budgets trade
/// agreement for the lane headroom `kv_codec` measures.
fn bench_layer_budgets() -> Result<Json> {
    const RANK: usize = 8;
    const PROMPT: usize = 8;
    let spec = StubSpec {
        n_layers: 2,
        n_heads: 2,
        rank: RANK,
        vocab: 16,
        max_positions: 128,
        batch_slots: BATCH_SLOTS,
        ..Default::default()
    };
    let mk = |now: Instant| -> Vec<Request> {
        (0..BATCH_SLOTS as u64)
            .map(|id| {
                Request::greedy(
                    id,
                    (0..PROMPT as i32).map(|p| (3 + p * 5 + id as i32) % 16).collect(),
                    24,
                    now,
                )
            })
            .collect()
    };
    let now = Instant::now();
    let identity = Engine::new_stub(spec.clone());
    let (baseline, _) = identity.serve_all(mk(now), policy())?;

    let profiles = [vec![8, 8], vec![4, 8], vec![4, 4], vec![2, 4], vec![2, 2]];
    let mut rows = Vec::new();
    for budgets in profiles {
        let engine = Engine::new_stub(spec.clone())
            .with_kv_codec(KvCodecSpec::Factored { layer_budgets: Some(budgets.clone()) })?;
        let bytes_per_token = engine.kv_bytes_per_token_total();
        let (completions, m) = engine.serve_all(mk(now), policy())?;
        // Mean fraction of each request's generated row that matches the
        // identity trace from the front (prompt excluded — it is the
        // input, not a prediction).
        let mut agreement = 0.0;
        for (a, b) in completions.iter().zip(&baseline) {
            let (ga, gb) = (&a.tokens[PROMPT..], &b.tokens[PROMPT..]);
            let lcp = ga.iter().zip(gb).take_while(|(x, y)| x == y).count();
            agreement += lcp as f64 / gb.len().max(1) as f64;
        }
        let agreement = agreement / baseline.len().max(1) as f64;
        println!(
            "layer budgets {budgets:?}: prefix agreement {agreement:5.3} vs identity \
             | {bytes_per_token:>3} B/token | {} completed",
            m.completed,
        );
        let mut o = BTreeMap::new();
        o.insert(
            "budgets".to_string(),
            Json::Arr(budgets.iter().map(|&r| Json::Num(r as f64)).collect()),
        );
        o.insert("bytes_per_token".to_string(), Json::Num(bytes_per_token as f64));
        o.insert("mean_prefix_agreement".to_string(), Json::Num(agreement));
        o.insert("completed".to_string(), Json::Num(m.completed as f64));
        rows.push(Json::Obj(o));
    }
    let mut o = BTreeMap::new();
    o.insert("backend".to_string(), Json::Str("stub".to_string()));
    o.insert("rank".to_string(), Json::Num(RANK as f64));
    o.insert("n_layers".to_string(), Json::Num(2.0));
    o.insert("requests".to_string(), Json::Num(BATCH_SLOTS as f64));
    o.insert("max_new".to_string(), Json::Num(24.0));
    o.insert("profiles".to_string(), Json::Arr(rows));
    Ok(Json::Obj(o))
}

/// TTFT and tokens/s vs prefix-share ratio under a Zipf-head prompt mix,
/// on virtual time.  At share s, that fraction of the 16 requests opens
/// with the hot 64-token prefix (the head of the Zipf distribution); the
/// rest are unique one-off prompts (the tail).  Each share is served
/// twice over an identical trace and a fixed `--kv-memory-budget` — once
/// with the radix prefix cache (32-token blocks), once cold — through a
/// serial 1-lane stub on a manual [`Clock`] with a per-slab-token width
/// delay, so TTFT is exact virtual time, not wall-clock noise: a cache
/// hit skips whole prefill chunks and the saving is deterministic.  The
/// acceptance bar (`scripts/check_bench.py`) reads this section: cache-on
/// mean TTFT must fall monotonically as the share rises, beat cache-off
/// outright at share >= 0.5, and stay bit-identical to the cold trace at
/// every share.  A final tight-budget row forces LRU-by-attention-mass
/// eviction mid-serve (`evicted_bytes > 0`) to pin the budget path.
fn bench_prefix_cache() -> Result<Json> {
    use clover::obs::Clock;
    use clover::serve::ServeMetrics;

    const REQS: usize = 16;
    const PROMPT: usize = 64;
    const MAX_NEW: usize = 8;
    const BLOCK: usize = 32;
    /// Ample: 64 identity pages at 2048 B — donations all fit until the
    /// very end of the share-0 sweep.
    const AMPLE_BUDGET: usize = 131_072;
    /// Tight: 12 pages — every unique donation overflows it, so the LRU
    /// sweep runs while requests are still arriving.
    const TIGHT_BUDGET: usize = 24_576;

    let mk_spec = |clock: Clock| StubSpec {
        n_layers: 1,
        n_heads: 2,
        rank: 8,
        vocab: 16,
        max_positions: 128,
        batch_slots: 1,
        step_delay: Duration::from_millis(1),
        width_delay: Duration::from_millis(1),
        clock,
        ..Default::default()
    };
    let hot: Vec<i32> = (0..PROMPT as i32).map(|i| (i * 5 + 3) % 16).collect();
    let mk_reqs = |hot_n: usize, now: Instant| -> Vec<Request> {
        (0..REQS as u64)
            .map(|id| {
                let prompt = if (id as usize) < hot_n {
                    hot.clone()
                } else {
                    // Tail prompts diverge from the hot prefix (and each
                    // other) inside the first block — no spurious hits.
                    (0..PROMPT as i32).map(|i| (i * 3 + id as i32 * 7 + 1) % 16).collect()
                };
                Request::greedy(id, prompt, MAX_NEW, now)
            })
            .collect()
    };
    let run = |hot_n: usize,
               block: Option<usize>,
               budget: usize|
     -> Result<(Vec<Completion>, ServeMetrics)> {
        let clock = Clock::manual();
        let engine = Engine::new_stub(mk_spec(clock.clone()))
            .with_kv_memory_budget(Some(budget))
            .with_prefix_cache(block)?;
        engine.serve_all(mk_reqs(hot_n, clock.now()), policy())
    };
    let mean_ttft = |c: &[Completion]| -> f64 {
        c.iter().map(|x| x.ttft_s).sum::<f64>() / c.len().max(1) as f64
    };
    let row = |share: f64, budget: usize| -> Result<Json> {
        let hot_n = (share * REQS as f64).round() as usize;
        let (warm, wm) = run(hot_n, Some(BLOCK), budget)?;
        let (cold, cm) = run(hot_n, None, budget)?;
        let bit_identical = warm.iter().zip(&cold).all(|(a, b)| a.tokens == b.tokens);
        let (on, off) = (mean_ttft(&warm), mean_ttft(&cold));
        println!(
            "prefix share {share:4.2}: ttft mean {on:6.3}s cached vs {off:6.3}s cold \
             | {:>2} hits ({:>3} tok skipped) | {:>3} vs {:>3} fused steps \
             | cached {} | evicted {} | bit-identical {bit_identical}",
            wm.prefix_hits,
            wm.prefix_hit_tokens,
            wm.decode_steps,
            cm.decode_steps,
            human_bytes(wm.prefix_cached_bytes),
            human_bytes(wm.prefix_evicted_bytes),
        );
        let mut o = BTreeMap::new();
        o.insert("share".to_string(), Json::Num(share));
        o.insert("hot_requests".to_string(), Json::Num(hot_n as f64));
        o.insert("prefix_hits".to_string(), Json::Num(wm.prefix_hits as f64));
        o.insert("prefix_hit_tokens".to_string(), Json::Num(wm.prefix_hit_tokens as f64));
        o.insert("ttft_mean_cache_on_s".to_string(), Json::Num(on));
        o.insert("ttft_mean_cache_off_s".to_string(), Json::Num(off));
        o.insert("ttft_p50_cache_on_s".to_string(), Json::Num(wm.ttft_p50_s));
        o.insert("ttft_p50_cache_off_s".to_string(), Json::Num(cm.ttft_p50_s));
        o.insert("tokens_per_s_cache_on".to_string(), Json::Num(wm.tokens_per_s()));
        o.insert("tokens_per_s_cache_off".to_string(), Json::Num(cm.tokens_per_s()));
        o.insert("decode_steps_cache_on".to_string(), Json::Num(wm.decode_steps as f64));
        o.insert("decode_steps_cache_off".to_string(), Json::Num(cm.decode_steps as f64));
        o.insert("cached_bytes".to_string(), Json::Num(wm.prefix_cached_bytes as f64));
        o.insert("evicted_bytes".to_string(), Json::Num(wm.prefix_evicted_bytes as f64));
        o.insert("memory_budget_bytes".to_string(), Json::Num(budget as f64));
        o.insert("bit_identical_to_cold".to_string(), Json::Bool(bit_identical));
        Ok(Json::Obj(o))
    };

    let mut sweep = Vec::new();
    for share in [0.0, 0.25, 0.5, 0.75, 1.0] {
        sweep.push(row(share, AMPLE_BUDGET)?);
    }
    let tight = row(0.5, TIGHT_BUDGET)?;

    let mut o = BTreeMap::new();
    o.insert("backend".to_string(), Json::Str("stub".to_string()));
    o.insert("mix".to_string(), Json::Str("zipf-head".to_string()));
    o.insert("requests".to_string(), Json::Num(REQS as f64));
    o.insert("prompt_tokens".to_string(), Json::Num(PROMPT as f64));
    o.insert("max_new".to_string(), Json::Num(MAX_NEW as f64));
    o.insert("block".to_string(), Json::Num(BLOCK as f64));
    o.insert("memory_budget_bytes".to_string(), Json::Num(AMPLE_BUDGET as f64));
    o.insert("sweep".to_string(), Json::Arr(sweep));
    o.insert("tight_budget".to_string(), tight);
    Ok(Json::Obj(o))
}

/// Goodput under injected faults, plus the two recovery drills — the
/// chaos-readiness section `scripts/check_bench.py` holds the bars to.
///
/// * **Rates sweep** — the same 16-request trace served at transient
///   fault rates 0 / 1% / 5% under `RetryPolicy { budget: 3, backoff:
///   1ms }` on a manual [`Clock`] with a 4 ms step delay, so "goodput"
///   is exact virtual time: a faulted attempt costs only its backoff
///   (the step committed nothing), never a lost request.  Bars: zero
///   lost requests at every rate, goodput at the 1% rate ≥ 0.9×
///   fault-free, every completed row bit-identical to the fault-free
///   serve.
/// * **Supervised recovery** — a gateway whose backend is scheduled to
///   die fatally at step 6 (`max_restarts: 2`): the supervisor rebuilds
///   the engine, defuses the spent death, and replays from the replay
///   book.  Restarts are read back from the shared registry
///   (`clover_engine_restarts_total`), and the recovered rows must be
///   bit-identical to an unfaulted gateway's.
/// * **Fleet failover** — a doomed engine (`max_restarts: 0`, orphan
///   parking on) beside a healthy sibling under a [`Router`]: a sidecar
///   polls `fail_over()` while the client drains, the doomed breaker is
///   forced Open, and every orphan completes on the sibling —
///   bit-identically, because replay resubmits `prompt ⧺ streamed`.
fn bench_fault_recovery() -> Result<Json> {
    use clover::obs::Clock;
    use clover::runtime::stub::FaultPlan;
    use clover::serve::{RetryPolicy, ServeMetrics};
    use clover::server::{
        EngineSpec, Gateway, GatewayConfig, Health, Obs, Router, StreamOutcome,
    };
    use std::collections::HashMap;
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};

    const REQS: usize = 16;
    const PROMPT: usize = 8;
    const MAX_NEW: usize = 16;
    /// Fault-schedule seed: deterministic, and chosen so neither sweep
    /// rate ever faults the same step twice in a row (the retry budget
    /// is never spent — nothing dies mid-sweep).
    const FAULT_SEED: u64 = 7;
    let retry = RetryPolicy { budget: 3, backoff: Duration::from_millis(1) };

    // ---- transient-rate sweep, virtual time -------------------------
    let mk_spec = |clock: Clock, rate: f64| StubSpec {
        batch_slots: BATCH_SLOTS,
        step_delay: Duration::from_millis(4),
        clock,
        fault_plan: FaultPlan {
            seed: FAULT_SEED,
            transient_rate: rate,
            ..Default::default()
        },
        ..Default::default()
    };
    let mk_reqs = |now: Instant| -> Vec<Request> {
        (0..REQS as u64)
            .map(|id| {
                Request::greedy(
                    id,
                    (0..PROMPT as i32).map(|p| (p * 3 + id as i32) % 32).collect(),
                    MAX_NEW,
                    now,
                )
            })
            .collect()
    };
    let run_rate = |rate: f64| -> Result<(Vec<Completion>, ServeMetrics)> {
        let clock = Clock::manual();
        let engine = Engine::new_stub(mk_spec(clock.clone(), rate)).with_retry_policy(retry);
        engine.serve_all(mk_reqs(clock.now()), policy())
    };
    // Fault-free oracle: rows keyed by each prompt's distinguishing
    // first token (id % 32 — distinct across the 16 requests).
    let (base_c, base_m) = run_rate(0.0)?;
    let base_goodput = base_m.tokens_per_s();
    let base_rows: HashMap<i32, Vec<i32>> =
        base_c.iter().map(|c| (c.tokens[0], c.tokens.clone())).collect();
    let mut rates = Vec::new();
    for rate in [0.0, 0.01, 0.05] {
        let (c, m) = run_rate(rate)?;
        let terminal = m.completed + m.cancelled + m.failed + m.migrated;
        let lost = REQS as f64 - terminal as f64;
        let goodput = m.tokens_per_s();
        let bit_identical = c
            .iter()
            .all(|x| base_rows.get(&x.tokens[0]).map_or(false, |b| *b == x.tokens));
        println!(
            "fault rate {rate:4.2}: {:2} completed, {} failed, {lost:.0} lost \
             | {:2} faults, {:2} retries | {goodput:7.1} tok/s virtual \
             ({:.3}x fault-free) | bit-identical {bit_identical}",
            m.completed,
            m.failed,
            m.step_faults,
            m.step_retries,
            goodput / base_goodput.max(1e-12),
        );
        let mut o = BTreeMap::new();
        o.insert("transient_rate".to_string(), Json::Num(rate));
        o.insert("completed".to_string(), Json::Num(m.completed as f64));
        o.insert("failed".to_string(), Json::Num(m.failed as f64));
        o.insert("lost".to_string(), Json::Num(lost));
        o.insert("step_faults".to_string(), Json::Num(m.step_faults as f64));
        o.insert("step_retries".to_string(), Json::Num(m.step_retries as f64));
        o.insert("generated_tokens".to_string(), Json::Num(m.generated_tokens as f64));
        o.insert("wall_s".to_string(), Json::Num(m.wall_s));
        o.insert("goodput_tokens_per_s".to_string(), Json::Num(goodput));
        o.insert(
            "goodput_vs_fault_free".to_string(),
            Json::Num(goodput / base_goodput.max(1e-12)),
        );
        o.insert("ttft_p50_s".to_string(), Json::Num(m.ttft_p50_s));
        o.insert("ttft_p99_s".to_string(), Json::Num(m.ttft_p99_s));
        o.insert("bit_identical_to_fault_free".to_string(), Json::Bool(bit_identical));
        rates.push(Json::Obj(o));
    }

    // ---- supervised recovery drill ----------------------------------
    // One gateway serve: submit 8 requests, wait out every stream, and
    // return (completed rows, failed count) — conservation means the two
    // always sum to 8.
    const SUP_REQS: usize = 8;
    let serve_rows =
        |name: &str, cfg: GatewayConfig, spec: StubSpec, obs: Option<Obs>| -> Result<(Vec<Vec<i32>>, usize)> {
            let gw = Gateway::spawn_with_obs(name, cfg, EngineSpec::stub(spec), obs)?;
            let mut tickets = Vec::new();
            for i in 0..SUP_REQS as i32 {
                tickets.push(
                    gw.submit(vec![10 + i, 2, 3], 8, SamplingParams::greedy(), None)
                        .map_err(|e| anyhow::anyhow!("{name} submit: {e}"))?,
                );
            }
            let mut done = Vec::new();
            let mut failed = 0usize;
            for t in tickets {
                match t.stream.wait()? {
                    StreamOutcome::Done(c) => done.push(c.tokens),
                    StreamOutcome::Cancelled { .. } | StreamOutcome::Failed { .. } => failed += 1,
                }
            }
            gw.join()?;
            Ok((done, failed))
        };
    let row_map = |rows: &[Vec<i32>]| -> HashMap<i32, Vec<i32>> {
        rows.iter().map(|r| (r[0], r.clone())).collect()
    };
    let identical_to = |rows: &[Vec<i32>], want: &HashMap<i32, Vec<i32>>| -> bool {
        rows.iter().all(|r| want.get(&r[0]).map_or(false, |w| w == r))
    };

    let (clean, _) = serve_rows("fault-clean", GatewayConfig::default(), StubSpec::default(), None)?;
    let want = row_map(&clean);
    let obs = Obs::default();
    let doomed_spec = StubSpec {
        step_delay: Duration::from_millis(2),
        fault_plan: FaultPlan {
            seed: FAULT_SEED,
            fatal_after_steps: Some(6),
            ..Default::default()
        },
        ..Default::default()
    };
    let t0 = Instant::now();
    let (recovered, rec_failed) = serve_rows(
        "fault-sup",
        GatewayConfig { max_restarts: 2, ..Default::default() },
        doomed_spec,
        Some(obs.clone()),
    )?;
    // Submit-to-drained for the whole faulted serve: the death, the
    // rebuild, the replay, and the resumed decode (wall clock — the
    // gateway thread is real time).
    let recovery_s = t0.elapsed().as_secs_f64();
    let restarts = obs
        .registry
        .get("clover_engine_restarts_total{gateway=\"fault-sup\"}")
        .unwrap_or(0.0);
    let rec_identical = identical_to(&recovered, &want);
    let rec_lost = SUP_REQS as f64 - (recovered.len() + rec_failed) as f64;
    println!(
        "recovery   : backend died at step 6, {restarts:.0} restart(s), drained in {recovery_s:.3}s \
         | {} completed, {rec_failed} failed, {rec_lost:.0} lost | bit-identical {rec_identical}",
        recovered.len(),
    );
    let mut rec = BTreeMap::new();
    rec.insert("requests".to_string(), Json::Num(SUP_REQS as f64));
    rec.insert("restarts".to_string(), Json::Num(restarts));
    rec.insert("recovery_s".to_string(), Json::Num(recovery_s));
    rec.insert("completed".to_string(), Json::Num(recovered.len() as f64));
    rec.insert("failed".to_string(), Json::Num(rec_failed as f64));
    rec.insert("lost".to_string(), Json::Num(rec_lost));
    rec.insert("bit_identical".to_string(), Json::Bool(rec_identical));

    // ---- fleet failover drill ---------------------------------------
    let doomed = Gateway::spawn(
        "fault-fo-a",
        GatewayConfig { max_restarts: 0, failover: true, ..Default::default() },
        EngineSpec::stub(StubSpec {
            step_delay: Duration::from_millis(2),
            fault_plan: FaultPlan {
                seed: FAULT_SEED,
                fatal_after_steps: Some(4),
                ..Default::default()
            },
            ..Default::default()
        }),
    )?;
    let sibling =
        Gateway::spawn("fault-fo-b", GatewayConfig::default(), EngineSpec::stub(StubSpec::default()))?;
    let router = Router::new(vec![doomed, sibling])?;
    let mut tickets = Vec::new();
    for i in 0..SUP_REQS as i32 {
        let (_, t) = router
            .submit(vec![10 + i, 2, 3], 8, SamplingParams::greedy(), None)
            .map_err(|e| anyhow::anyhow!("failover submit: {e}"))?;
        tickets.push(t);
    }
    // The failover sweep needs a live caller while the client blocks in
    // `wait`: poll it from a scoped sidecar until the streams drain.
    let drained = AtomicBool::new(false);
    let moved = AtomicUsize::new(0);
    // Collect the raw waits first and only `?` after the sidecar has been
    // released — an early return inside the scope would leave it looping
    // and hang the scope join.
    let outcomes: Vec<Result<StreamOutcome>> = std::thread::scope(|s| {
        s.spawn(|| {
            while !drained.load(Ordering::SeqCst) {
                moved.fetch_add(router.fail_over(), Ordering::SeqCst);
                std::thread::sleep(Duration::from_millis(5));
            }
        });
        let outs: Vec<_> = tickets.into_iter().map(|t| t.stream.wait()).collect();
        drained.store(true, Ordering::SeqCst);
        outs
    });
    let mut fo_done = Vec::new();
    let mut fo_failed = 0usize;
    for outcome in outcomes {
        match outcome? {
            StreamOutcome::Done(c) => fo_done.push(c.tokens),
            StreamOutcome::Cancelled { .. } | StreamOutcome::Failed { .. } => fo_failed += 1,
        }
    }
    let failed_over = moved.load(Ordering::SeqCst);
    let breaker_open = router.health(0) == Health::Open;
    let fo_identical = identical_to(&fo_done, &want);
    let fo_lost = SUP_REQS as f64 - (fo_done.len() + fo_failed) as f64;
    // The doomed worker died by design; the router's join surfaces it.
    let _ = router.join();
    println!(
        "failover   : {failed_over} orphan(s) re-homed, breaker open {breaker_open} \
         | {} completed, {fo_failed} failed, {fo_lost:.0} lost | bit-identical {fo_identical}",
        fo_done.len(),
    );
    let mut fo = BTreeMap::new();
    fo.insert("requests".to_string(), Json::Num(SUP_REQS as f64));
    fo.insert("failed_over".to_string(), Json::Num(failed_over as f64));
    fo.insert("breaker_open".to_string(), Json::Bool(breaker_open));
    fo.insert("completed".to_string(), Json::Num(fo_done.len() as f64));
    fo.insert("failed".to_string(), Json::Num(fo_failed as f64));
    fo.insert("lost".to_string(), Json::Num(fo_lost));
    fo.insert("bit_identical".to_string(), Json::Bool(fo_identical));

    let mut o = BTreeMap::new();
    o.insert("backend".to_string(), Json::Str("stub".to_string()));
    o.insert("fault_seed".to_string(), Json::Num(FAULT_SEED as f64));
    o.insert("requests".to_string(), Json::Num(REQS as f64));
    o.insert("prompt_tokens".to_string(), Json::Num(PROMPT as f64));
    o.insert("max_new".to_string(), Json::Num(MAX_NEW as f64));
    let mut r = BTreeMap::new();
    r.insert("budget".to_string(), Json::Num(retry.budget as f64));
    r.insert("backoff_ms".to_string(), Json::Num(retry.backoff.as_millis() as f64));
    o.insert("retry".to_string(), Json::Obj(r));
    o.insert("rates".to_string(), Json::Arr(rates));
    o.insert("recovery".to_string(), Json::Obj(rec));
    o.insert("failover".to_string(), Json::Obj(fo));
    Ok(Json::Obj(o))
}

/// Observability taps: tokens/s untapped vs tapped (the <5% overhead
/// bar), span-reconstructed aggregates vs the engine's own
/// [`clover::serve::ServeMetrics`] (the fidelity bar), and the dumps the
/// CI artifact upload reads — `BENCH_trace.json` (Chrome trace-event,
/// Perfetto-loadable) from the tapped run and `BENCH_metrics.json`
/// (registry dump) from a stub gateway publishing through a shared
/// [`clover::server::Obs`].
fn bench_obs() -> Result<Json> {
    use clover::obs::TraceSink;
    use clover::serve::NoHook;
    use clover::server::{EngineSpec, Gateway, GatewayConfig, Obs};

    const REQS: u64 = 64;
    const PROMPT: usize = 16;
    let spec = StubSpec { max_positions: 128, batch_slots: BATCH_SLOTS, ..Default::default() };
    let mk = |now: Instant| -> Vec<Request> {
        (0..REQS)
            .map(|id| {
                Request::greedy(
                    id,
                    (0..PROMPT as i32).map(|i| (i * 3 + id as i32) % 32).collect(),
                    16 + (id as usize % 4) * 8,
                    now,
                )
            })
            .collect()
    };
    // Best-of-3 each way: the tap cost is per-step and tiny, so compare
    // against the stub's real per-step work (no artificial delay) over a
    // long enough trace that wall-clock noise averages out.
    let mut best_base = 0.0f64;
    let mut best_tap = 0.0f64;
    for _ in 0..3 {
        let engine = Engine::new_stub(spec.clone());
        let (_, m) =
            engine.serve_hooked(mk(Instant::now()), policy(), Admission::Continuous, &mut NoHook)?;
        best_base = best_base.max(m.tokens_per_s());
        let engine = Engine::new_stub(spec.clone());
        let mut sink = TraceSink::default();
        let (_, m) =
            engine.serve_hooked(mk(Instant::now()), policy(), Admission::Continuous, &mut sink)?;
        best_tap = best_tap.max(m.tokens_per_s());
    }
    let overhead = ((best_base - best_tap) / best_base.max(1e-12)).max(0.0);
    println!(
        "obs taps   : {best_base:.0} tok/s untapped vs {best_tap:.0} tapped \
         ({:.2}% overhead)",
        100.0 * overhead,
    );

    // Fidelity run: one tapped serve whose span timelines must
    // reconstruct the engine's own aggregates.
    let engine = Engine::new_stub(spec.clone());
    let mut sink = TraceSink::default();
    let (_, m) =
        engine.serve_hooked(mk(Instant::now()), policy(), Admission::Continuous, &mut sink)?;
    let recon = sink.reconstruct();
    println!(
        "obs recon  : {}/{} completed, {}/{} generated, ttft p50 {:.6}/{:.6}s \
         | {} spans ({} open) | {} step events",
        recon.completed,
        m.completed,
        recon.generated_tokens,
        m.generated_tokens,
        recon.ttft_p50_s,
        m.ttft_p50_s,
        sink.spans().count(),
        sink.open_spans(),
        sink.steps_seen(),
    );
    std::fs::write("BENCH_trace.json", json::to_string(&sink.chrome_trace()))?;
    println!("wrote BENCH_trace.json");

    // Gateway aggregate: the same stub behind a worker thread publishing
    // into a shared registry; its counter series must agree with the
    // engine's final metrics.
    let obs = Obs::default();
    let gateway = Gateway::spawn_with_obs(
        "bench",
        GatewayConfig::default(),
        EngineSpec::stub(spec),
        Some(obs.clone()),
    )?;
    let mut tickets = Vec::new();
    for id in 0..BATCH_SLOTS as i32 {
        let prompt: Vec<i32> = (0..8).map(|p| (p + id) % 32).collect();
        let t = gateway
            .submit(prompt, 8, SamplingParams::greedy(), None)
            .map_err(|e| anyhow::anyhow!("bench submit: {e}"))?;
        tickets.push(t);
    }
    let gm = gateway.join()?;
    drop(tickets);
    let reg = |name: &str| {
        obs.registry.get(&format!("{name}{{gateway=\"bench\"}}")).unwrap_or(-1.0)
    };
    let reg_completed = reg("clover_completed_total");
    let reg_generated = reg("clover_generated_tokens_total");
    println!(
        "obs gateway: registry {reg_completed:.0} completed / {reg_generated:.0} generated \
         (engine {} / {})",
        gm.completed, gm.generated_tokens,
    );
    std::fs::write("BENCH_metrics.json", json::to_string(&obs.registry.to_json()))?;
    println!("wrote BENCH_metrics.json");

    let mut o = BTreeMap::new();
    o.insert("backend".to_string(), Json::Str("stub".to_string()));
    o.insert("requests".to_string(), Json::Num(REQS as f64));
    o.insert("prompt_tokens".to_string(), Json::Num(PROMPT as f64));
    o.insert("baseline_tokens_per_s".to_string(), Json::Num(best_base));
    o.insert("tapped_tokens_per_s".to_string(), Json::Num(best_tap));
    o.insert("tap_overhead_frac".to_string(), Json::Num(overhead));
    let mut r = BTreeMap::new();
    r.insert("completed".to_string(), Json::Num(recon.completed as f64));
    r.insert("cancelled".to_string(), Json::Num(recon.cancelled as f64));
    r.insert("generated_tokens".to_string(), Json::Num(recon.generated_tokens as f64));
    r.insert("ttft_p50_s".to_string(), Json::Num(recon.ttft_p50_s));
    r.insert("ttft_p99_s".to_string(), Json::Num(recon.ttft_p99_s));
    o.insert("recon".to_string(), Json::Obj(r));
    let mut e = BTreeMap::new();
    e.insert("completed".to_string(), Json::Num(m.completed as f64));
    e.insert("cancelled".to_string(), Json::Num(m.cancelled as f64));
    e.insert("generated_tokens".to_string(), Json::Num(m.generated_tokens as f64));
    e.insert("ttft_p50_s".to_string(), Json::Num(m.ttft_p50_s));
    e.insert("ttft_p99_s".to_string(), Json::Num(m.ttft_p99_s));
    e.insert("decode_steps".to_string(), Json::Num(m.decode_steps as f64));
    o.insert("metrics".to_string(), Json::Obj(e));
    o.insert("steps_seen".to_string(), Json::Num(sink.steps_seen() as f64));
    o.insert("open_spans".to_string(), Json::Num(sink.open_spans() as f64));
    let mut g = BTreeMap::new();
    g.insert("completed".to_string(), Json::Num(gm.completed as f64));
    g.insert("generated_tokens".to_string(), Json::Num(gm.generated_tokens as f64));
    g.insert("registry_completed".to_string(), Json::Num(reg_completed));
    g.insert("registry_generated_tokens".to_string(), Json::Num(reg_generated));
    o.insert("gateway".to_string(), Json::Obj(g));
    o.insert("trace_file".to_string(), Json::Str("BENCH_trace.json".to_string()));
    o.insert("metrics_file".to_string(), Json::Str("BENCH_metrics.json".to_string()));
    Ok(Json::Obj(o))
}

/// End-to-end engines over the compiled artifacts (wave vs continuous,
/// dense vs pruned ranks).  Returns the per-engine records.
fn bench_pjrt_engines(rt: &Runtime) -> Result<Vec<Json>> {
    let preset = "tiny";
    let entry = rt.manifest().config(preset)?.clone();
    let dense = ops::init_params(rt, preset, 1)?;
    let now = Instant::now();
    let d_head = entry.dim("d_head")?;

    let mut results: Vec<Json> = Vec::new();
    let mut run = |name: &str, rank: usize, engine: &Engine, mode: Admission| -> Result<usize> {
        // Warm the executables so compile time doesn't pollute the split.
        engine.serve_with(mk_requests(now), policy(), mode)?;
        rt.reset_stats();
        let (_, m) = engine.serve_with(mk_requests(now), policy(), mode)?;
        let st = rt.stats();
        let mode_s = match mode {
            Admission::Continuous => "continuous",
            Admission::WaveToCompletion => "wave",
        };
        println!(
            "engine {name:<6} [{mode_s:<10}]: {:6.1} tok/s  {:3} steps  ttft p50 {:.3}s  lat p50/p99 {:.3}/{:.3}s  peak KV {}  (marshal {:4.1}%  execute {:4.1}%)",
            m.tokens_per_s(), m.decode_steps, m.ttft_p50_s,
            m.latency_p50_s, m.latency_p99_s, human_bytes(m.kv_peak_bytes),
            100.0 * st.marshal_s / m.wall_s, 100.0 * st.execute_s / m.wall_s,
        );
        let mut o = BTreeMap::new();
        o.insert("name".to_string(), Json::Str(name.to_string()));
        o.insert("rank".to_string(), Json::Num(rank as f64));
        o.insert("mode".to_string(), Json::Str(mode_s.to_string()));
        o.insert("ladder".to_string(),
                 Json::Arr(engine.widths().iter().map(|&w| Json::Num(w as f64)).collect()));
        o.insert("tokens_per_s".to_string(), Json::Num(m.tokens_per_s()));
        o.insert("decode_steps".to_string(), Json::Num(m.decode_steps as f64));
        o.insert("slab_tokens".to_string(), Json::Num(m.slab_tokens as f64));
        o.insert("admissions".to_string(), Json::Num(m.admissions as f64));
        o.insert("ttft_p50_s".to_string(), Json::Num(m.ttft_p50_s));
        o.insert("ttft_p99_s".to_string(), Json::Num(m.ttft_p99_s));
        o.insert("latency_p50_s".to_string(), Json::Num(m.latency_p50_s));
        o.insert("latency_p99_s".to_string(), Json::Num(m.latency_p99_s));
        o.insert("kv_peak_bytes".to_string(), Json::Num(m.kv_peak_bytes as f64));
        o.insert("wall_s".to_string(), Json::Num(m.wall_s));
        o.insert("marshal_s".to_string(), Json::Num(st.marshal_s));
        o.insert("execute_s".to_string(), Json::Num(st.execute_s));
        results.push(Json::Obj(o));
        Ok(m.decode_steps)
    };

    let mut engines: Vec<(String, usize, Engine)> = Vec::new();
    engines.push((
        "dense".to_string(),
        d_head,
        Engine::new(rt, preset, &format!("decode_b{BATCH_SLOTS}"), dense.clone())?,
    ));
    for ratio in [0.5, 0.75] {
        let (fac, r) = ops::prune_to_ratio(&entry, &dense, ratio, "clover")?;
        engines.push((
            format!("r={r}"),
            r,
            Engine::new(rt, preset, &format!("decode_fac_r{r}_b{BATCH_SLOTS}"), fac)?,
        ));
    }

    for (name, rank, engine) in &engines {
        let wave = run(name, *rank, engine, Admission::WaveToCompletion)?;
        let cont = run(name, *rank, engine, Admission::Continuous)?;
        println!(
            "engine {name:<6} continuous batching saves {} of {wave} decode steps ({:.0}%)",
            wave.saturating_sub(cont),
            100.0 * wave.saturating_sub(cont) as f64 / wave.max(1) as f64,
        );
    }
    Ok(results)
}

fn main() -> Result<()> {
    println!("== perf_serve ==");

    // Batcher micro-bench: admission throughput.
    {
        let now = Instant::now();
        let n = 200_000;
        let t0 = Instant::now();
        let mut b = Batcher::new(BatchPolicy { max_batch: 8, max_wait: Duration::ZERO });
        let mut admitted = 0usize;
        for i in 0..n {
            b.push(Request::greedy(i, vec![1], 1, now));
            if b.ready(now, false) {
                admitted += b.take_batch().len();
            }
        }
        admitted += b.take_batch().len();
        let dt = t0.elapsed().as_secs_f64();
        println!("batcher    : {:.1}M req/s (admitted {admitted})", n as f64 / dt / 1e6);
    }

    // KV allocator churn — slab-granular advances.
    {
        let cfg = KvConfig {
            n_layers: 4,
            n_heads: 8,
            rank: 16,
            max_positions: 128,
            batch_slots: 8,
            codec: KvCodecSpec::Identity,
        };
        let mut kv = KvManager::new(cfg);
        let n = 100_000;
        let t0 = Instant::now();
        for i in 0..n {
            let s = kv.allocate(i).unwrap();
            kv.advance_by(s, 8).unwrap();
            kv.free(s).unwrap();
        }
        let dt = t0.elapsed().as_secs_f64();
        println!("kv manager : {:.2}M alloc-slab8-free/s", n as f64 / dt / 1e6);
    }

    let mut root = BTreeMap::new();
    root.insert("bench".to_string(), Json::Str("perf_serve".to_string()));
    root.insert("preset".to_string(), Json::Str("tiny".to_string()));
    root.insert("requests".to_string(), Json::Num(N_REQUESTS as f64));
    root.insert("batch_slots".to_string(), Json::Num(BATCH_SLOTS as f64));

    // Chunked prefill: stub-backed, runs everywhere.
    root.insert("prefill".to_string(), bench_prefill_chunks()?);

    // Self-speculative decoding: stub pair, runs everywhere.
    root.insert("speculative".to_string(), bench_speculative()?);

    // Page codecs: lanes at fixed KV memory, stub-backed, runs everywhere.
    root.insert("kv_codec".to_string(), bench_kv_codecs()?);

    // Per-layer rank budgets: greedy agreement vs the identity baseline.
    root.insert("layer_budgets".to_string(), bench_layer_budgets()?);

    // Observability taps: overhead + trace fidelity; also writes the
    // BENCH_trace.json / BENCH_metrics.json artifacts.
    root.insert("obs".to_string(), bench_obs()?);

    // Radix prefix cache: TTFT vs share under a Zipf-head mix, virtual
    // time, runs everywhere.
    root.insert("prefix_cache".to_string(), bench_prefix_cache()?);

    // Fault injection: goodput under transient faults, supervised
    // recovery, and fleet failover — always on (stub backend).
    root.insert("fault_recovery".to_string(), bench_fault_recovery()?);

    // End-to-end engines need the compiled artifacts + live PJRT.
    match Runtime::new("artifacts") {
        Ok(rt) => {
            root.insert("pjrt_skipped".to_string(), Json::Bool(false));
            root.insert("engines".to_string(), Json::Arr(bench_pjrt_engines(&rt)?));
        }
        Err(e) => {
            println!("runtime unavailable, skipping the PJRT engine section\n  ({e:#})");
            root.insert("pjrt_skipped".to_string(), Json::Bool(true));
            root.insert("pjrt_skip_reason".to_string(), Json::Str(format!("{e:#}")));
            root.insert("engines".to_string(), Json::Arr(Vec::new()));
        }
    }

    std::fs::write("BENCH_serve.json", json::to_string(&Json::Obj(root)))?;
    println!("wrote BENCH_serve.json");
    Ok(())
}
