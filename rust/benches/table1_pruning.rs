//! Bench: regenerate the paper's Table 1 on this testbed.
//! `cargo bench --bench table1_pruning` (add `-- --full` for paper-scale budgets).
use clover::coordinator::experiments::{self, ExpOpts};
use clover::runtime::Runtime;
use clover::util::Stopwatch;

fn main() -> anyhow::Result<()> {
    let full = std::env::args().any(|a| a == "--full");
    let sw = Stopwatch::new();
    let rt = Runtime::new("artifacts")?;
    let opts = ExpOpts { preset: "tiny".into(), quick: !full, seed: 42 };
    let table = experiments::table1(&rt, &opts)?;
    table.emit("table1_pruning")?;
    println!("[table1_pruning] total {:.1}s", sw.elapsed_s());
    Ok(())
}
