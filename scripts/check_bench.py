#!/usr/bin/env python3
"""Sanity-check emitted BENCH_*.json artifacts against their schemas.

The perf benches (`cargo bench --bench perf_serve` / `perf_server`) write
machine-readable JSON so the serving-perf trajectory is comparable across
PRs.  This checker enforces the contract documented in
docs/BENCH_SCHEMAS.md: the required keys are present and every number is
finite (a NaN tokens/s or an Infinity TTFT means a bench divided by a
zero wall-clock — a bug, not a measurement).

Usage:  python3 scripts/check_bench.py rust/BENCH_serve.json rust/BENCH_server.json
        python3 scripts/check_bench.py rust/BENCH_trace.json rust/BENCH_metrics.json
        python3 scripts/check_bench.py --baseline BENCH_history/BENCH_serve.json \
            rust/BENCH_serve.json

Documents without a `bench` id are dispatched on shape: a top-level
`traceEvents` array is checked as a Chrome trace-event dump (step lane
time-ordered, one complete span per request, first-token marks inside
their spans), and a `counters`/`gauges` pair as a metrics-registry dump
(cumulative histogram buckets).  The perf_serve `obs` section gates the
observability bars: TraceSink taps < 5% tokens/s overhead, and the
span-reconstructed aggregates equal to the engine's own ServeMetrics.

With `--baseline`, fresh documents whose `bench` id matches the snapshot
are also diffed row-by-row against it (prefill chunks matched by `chunk`,
the speculative sweep by `draft_len`, the codec sweep by `codec`): a
throughput metric falling below 85% of the baseline, or a step-count /
steps-per-token metric rising above 115%, fails the check.  Baseline
values that are null or missing are skipped — the committed bootstrap
snapshot carries nulls for wall-clock metrics until a toolchain run
fills them (see BENCH_history/README.md).

Exit code 0 when every file passes; 1 with a per-file report otherwise.
Stdlib only — runs anywhere CI has a python3.
"""

from __future__ import annotations

import json
import math
import sys


def finite_numbers(node, path="$"):
    """Yield an error string for every non-finite number in the tree."""
    if isinstance(node, bool):
        return
    if isinstance(node, (int, float)):
        if not math.isfinite(node):
            yield f"{path}: non-finite number {node!r}"
    elif isinstance(node, dict):
        for k, v in node.items():
            yield from finite_numbers(v, f"{path}.{k}")
    elif isinstance(node, list):
        for i, v in enumerate(node):
            yield from finite_numbers(v, f"{path}[{i}]")


def require(doc, keys, path="$"):
    for k in keys:
        if k not in doc:
            yield f"{path}: missing required key {k!r}"


def check_serve(doc):
    yield from require(doc, ["bench", "preset", "prefill", "speculative", "kv_codec",
                             "layer_budgets", "obs", "prefix_cache", "fault_recovery",
                             "engines", "pjrt_skipped"])
    prefill = doc.get("prefill", {})
    yield from require(prefill, ["backend", "prompt_tokens", "ladder", "chunks"],
                       "$.prefill")
    chunks = prefill.get("chunks", [])
    if not chunks:
        yield "$.prefill.chunks: empty — the chunk ladder was not benched"
    for i, row in enumerate(chunks):
        yield from require(
            row,
            ["chunk", "prefill_steps", "decode_steps", "ttft_p50_s", "tokens_per_s",
             "prefill_step_reduction_vs_k1"],
            f"$.prefill.chunks[{i}]")
    # The acceptance bar: some chunk width >= 8 cuts prefill steps >= 4x
    # vs the single-token path.
    reductions = [row.get("prefill_step_reduction_vs_k1", 0)
                  for row in chunks if row.get("chunk", 0) >= 8]
    if reductions and max(reductions) < 4:
        yield (f"$.prefill: best prefill step reduction {max(reductions)}x < 4x "
               "for a chunked width")
    spec = doc.get("speculative", {})
    yield from require(
        spec, ["backend", "target_rank", "draft_rank", "vanilla_steps_per_token", "sweep"],
        "$.speculative")
    sweep = spec.get("sweep", [])
    if not sweep:
        yield "$.speculative.sweep: empty — the draft-length sweep was not benched"
    for i, row in enumerate(sweep):
        yield from require(
            row,
            ["draft_len", "acceptance_rate", "dense_steps_per_token", "draft_steps",
             "rollback_tokens", "bit_identical_to_vanilla"],
            f"$.speculative.sweep[{i}]")
        if not row.get("bit_identical_to_vanilla", False):
            yield (f"$.speculative.sweep[{i}]: speculative greedy output diverged from "
                   "vanilla greedy decode — the bit-identity invariant is broken")
    # The acceptance bar: some draft length >= 4 runs the dense decode at
    # < 1.0 steps per generated token — and strictly beats the vanilla
    # trace (vanilla sits at ~1.0 minus the prefill-boundary token, so
    # beating it is the part that proves speculation pays).
    vanilla = spec.get("vanilla_steps_per_token", 1.0)
    spt = [row.get("dense_steps_per_token", 1.0)
           for row in sweep if row.get("draft_len", 0) >= 4]
    if spt and min(spt) >= 1.0:
        yield (f"$.speculative: best dense steps-per-token {min(spt)} >= 1.0 at "
               "draft length >= 4 — speculation is not paying for itself")
    if spt and min(spt) >= vanilla:
        yield (f"$.speculative: best dense steps-per-token {min(spt)} does not "
               f"beat the vanilla trace ({vanilla})")
    kvc = doc.get("kv_codec", {})
    yield from require(
        kvc, ["backend", "rank", "requests", "memory_budget_bytes", "codecs"],
        "$.kv_codec")
    codecs = kvc.get("codecs", [])
    if not codecs:
        yield "$.kv_codec.codecs: empty — the codec sweep was not benched"
    identity = next((r for r in codecs if r.get("codec") == "identity"), None)
    if codecs and identity is None:
        yield "$.kv_codec.codecs: no identity row to compare against"
    for i, row in enumerate(codecs):
        yield from require(
            row,
            ["codec", "layer_budgets", "bytes_per_token", "bytes_per_page",
             "max_concurrent_lanes", "completed", "tokens_per_s"],
            f"$.kv_codec.codecs[{i}]")
        if identity is None or row is identity:
            continue
        # The acceptance bar: under the same byte budget, the factored
        # codec's smaller pages must buy at least 2x the measured
        # concurrent lanes (and cost at most half the bytes per token).
        if row.get("bytes_per_token", math.inf) * 2 > identity.get("bytes_per_token", 0):
            yield (f"$.kv_codec.codecs[{i}]: factored bytes/token "
                   f"{row.get('bytes_per_token')} not <= half the identity codec's "
                   f"{identity.get('bytes_per_token')}")
        if row.get("max_concurrent_lanes", 0) < 2 * identity.get("max_concurrent_lanes",
                                                                 math.inf):
            yield (f"$.kv_codec.codecs[{i}]: {row.get('max_concurrent_lanes')} concurrent "
                   f"lanes < 2x the identity codec's "
                   f"{identity.get('max_concurrent_lanes')} at the same memory budget")
    lb = doc.get("layer_budgets", {})
    yield from require(lb, ["backend", "rank", "n_layers", "profiles"], "$.layer_budgets")
    profiles = lb.get("profiles", [])
    if not profiles:
        yield "$.layer_budgets.profiles: empty — the budget sweep was not benched"
    rank = lb.get("rank", 0)
    full_seen = False
    for i, row in enumerate(profiles):
        yield from require(
            row, ["budgets", "bytes_per_token", "mean_prefix_agreement", "completed"],
            f"$.layer_budgets.profiles[{i}]")
        budgets = row.get("budgets", [])
        for b in budgets:
            if isinstance(b, bool) or not isinstance(b, (int, float)) or not 1 <= b <= rank:
                yield f"$.layer_budgets.profiles[{i}]: budget {b!r} outside 1..={rank}"
        agree = row.get("mean_prefix_agreement", -1.0)
        if isinstance(agree, bool) or not isinstance(agree, (int, float)) \
                or not 0.0 <= agree <= 1.0:
            yield (f"$.layer_budgets.profiles[{i}]: mean_prefix_agreement {agree!r} "
                   "is not a fraction in [0, 1]")
        elif budgets and all(b == rank for b in budgets):
            full_seen = True
            # Full budgets make the factored codec a pure copy, so the
            # greedy trace must match the identity baseline exactly.
            if agree != 1.0:
                yield (f"$.layer_budgets.profiles[{i}]: full-rank budgets must agree "
                       f"exactly with the identity trace (got {agree})")
    if profiles and not full_seen:
        yield "$.layer_budgets: no full-rank profile — the pure-copy anchor is missing"
    obs = doc.get("obs", {})
    yield from require(
        obs,
        ["backend", "baseline_tokens_per_s", "tapped_tokens_per_s", "tap_overhead_frac",
         "recon", "metrics", "steps_seen", "open_spans", "gateway"],
        "$.obs")
    # The acceptance bar: step/span taps cost < 5% tokens/s on the stub.
    frac = _metric(obs, "tap_overhead_frac")
    if frac is None or frac >= 0.05:
        yield (f"$.obs: tap_overhead_frac {obs.get('tap_overhead_frac')!r} is not "
               "< 0.05 — the TraceSink taps cost more than the 5% bar allows")
    # The fidelity bar: span timelines reconstruct the engine's own
    # aggregates — exact counts, float-tolerance TTFT percentiles.
    recon, metrics = obs.get("recon", {}), obs.get("metrics", {})
    for key in ("completed", "cancelled", "generated_tokens"):
        if recon.get(key) != metrics.get(key):
            yield (f"$.obs: recon.{key} {recon.get(key)!r} != metrics.{key} "
                   f"{metrics.get(key)!r} — the span timelines lost events")
    for key in ("ttft_p50_s", "ttft_p99_s"):
        rv, mv = _metric(recon, key), _metric(metrics, key)
        if rv is None or mv is None or abs(rv - mv) > 1e-6:
            yield (f"$.obs: recon.{key} {recon.get(key)!r} vs metrics.{key} "
                   f"{metrics.get(key)!r} differ beyond 1e-6")
    if obs.get("open_spans") != 0:
        yield (f"$.obs: open_spans {obs.get('open_spans')!r} != 0 — some request "
               "span never saw a terminal event")
    if obs.get("steps_seen") != metrics.get("decode_steps"):
        yield (f"$.obs: steps_seen {obs.get('steps_seen')!r} != decode_steps "
               f"{metrics.get('decode_steps')!r} — step events were dropped")
    gw = obs.get("gateway", {})
    if gw.get("registry_completed") != gw.get("completed") \
            or gw.get("registry_generated_tokens") != gw.get("generated_tokens"):
        yield (f"$.obs.gateway: registry counters {gw.get('registry_completed')!r}/"
               f"{gw.get('registry_generated_tokens')!r} disagree with the engine's "
               f"{gw.get('completed')!r}/{gw.get('generated_tokens')!r}")
    pc = doc.get("prefix_cache", {})
    yield from require(
        pc,
        ["backend", "mix", "requests", "prompt_tokens", "block", "memory_budget_bytes",
         "sweep", "tight_budget"],
        "$.prefix_cache")
    pc_sweep = pc.get("sweep", [])
    if not pc_sweep:
        yield "$.prefix_cache.sweep: empty — the share sweep was not benched"
    prev_on = None
    for i, row in enumerate(pc_sweep):
        yield from require(
            row,
            ["share", "hot_requests", "prefix_hits", "prefix_hit_tokens",
             "ttft_mean_cache_on_s", "ttft_mean_cache_off_s", "tokens_per_s_cache_on",
             "tokens_per_s_cache_off", "cached_bytes", "evicted_bytes",
             "bit_identical_to_cold"],
            f"$.prefix_cache.sweep[{i}]")
        if not row.get("bit_identical_to_cold", False):
            yield (f"$.prefix_cache.sweep[{i}]: cached serve diverged from the cold "
                   "prefill trace — the bit-identity invariant is broken")
        share = _metric(row, "share")
        on = _metric(row, "ttft_mean_cache_on_s")
        off = _metric(row, "ttft_mean_cache_off_s")
        # The acceptance bar: at share >= 0.5 the cache must win TTFT
        # outright at the same memory budget.
        if share is not None and share >= 0.5 and on is not None and off is not None \
                and on >= off:
            yield (f"$.prefix_cache.sweep[{i}]: cache-on mean TTFT {on:g}s >= "
                   f"cache-off {off:g}s at share {share:g} — the prefix cache "
                   "is not paying")
        # And monotone: raising the share at fixed memory never hurts TTFT
        # (virtual-time stub, so this is deterministic, not noise).
        if on is not None and prev_on is not None and on > prev_on + 1e-9:
            yield (f"$.prefix_cache.sweep[{i}]: cache-on mean TTFT {on:g}s rose "
                   f"above the previous share's {prev_on:g}s — TTFT must improve "
                   "monotonically with the prefix share")
        if on is not None:
            prev_on = on
    tight = pc.get("tight_budget", {})
    yield from require(
        tight, ["share", "memory_budget_bytes", "evicted_bytes", "bit_identical_to_cold"],
        "$.prefix_cache.tight_budget")
    ev = _metric(tight, "evicted_bytes")
    if tight and (ev is None or ev <= 0):
        yield (f"$.prefix_cache.tight_budget: evicted_bytes "
               f"{tight.get('evicted_bytes')!r} not > 0 — the tight budget never "
               "forced an eviction")
    if tight and not tight.get("bit_identical_to_cold", False):
        yield ("$.prefix_cache.tight_budget: eviction under pressure broke "
               "bit-identity to the cold trace")
    fr = doc.get("fault_recovery", {})
    yield from require(
        fr, ["backend", "fault_seed", "requests", "retry", "rates", "recovery",
             "failover"],
        "$.fault_recovery")
    fr_rates = fr.get("rates", [])
    if not fr_rates:
        yield "$.fault_recovery.rates: empty — the transient-rate sweep was not benched"
    if fr_rates and not any(_metric(r, "transient_rate") == 0.0 for r in fr_rates):
        yield "$.fault_recovery.rates: no fault-free (rate 0) row to compare against"
    for i, row in enumerate(fr_rates):
        yield from require(
            row,
            ["transient_rate", "completed", "failed", "lost", "step_faults",
             "step_retries", "goodput_tokens_per_s", "goodput_vs_fault_free",
             "ttft_p99_s", "bit_identical_to_fault_free"],
            f"$.fault_recovery.rates[{i}]")
        # The conservation bar: no injection rate may lose a request —
        # every accepted request ends in exactly one terminal event.
        if _metric(row, "lost") != 0:
            yield (f"$.fault_recovery.rates[{i}]: lost {row.get('lost')!r} != 0 — "
                   "a request vanished without a terminal event")
        if not row.get("bit_identical_to_fault_free", False):
            yield (f"$.fault_recovery.rates[{i}]: completed rows diverged from the "
                   "fault-free serve — retry broke the bit-identity invariant")
        rate = _metric(row, "transient_rate")
        ratio = _metric(row, "goodput_vs_fault_free")
        # The goodput bar: at a 1% transient rate, retries must keep >=
        # 90% of fault-free goodput (virtual time, so this is exact).
        if rate is not None and abs(rate - 0.01) < 1e-12 \
                and (ratio is None or ratio < 0.9):
            yield (f"$.fault_recovery.rates[{i}]: goodput_vs_fault_free "
                   f"{row.get('goodput_vs_fault_free')!r} < 0.9 at the 1% transient "
                   "rate — recovery costs more than the bar allows")
    rec = fr.get("recovery", {})
    yield from require(
        rec, ["requests", "restarts", "recovery_s", "completed", "failed", "lost",
              "bit_identical"],
        "$.fault_recovery.recovery")
    if rec:
        if _metric(rec, "lost") != 0:
            yield (f"$.fault_recovery.recovery: lost {rec.get('lost')!r} != 0 — "
                   "supervision dropped a request")
        restarts = _metric(rec, "restarts")
        if restarts is None or restarts < 1:
            yield (f"$.fault_recovery.recovery: restarts {rec.get('restarts')!r} < 1 "
                   "— the scheduled death never exercised the supervisor")
        if not rec.get("bit_identical", False):
            yield ("$.fault_recovery.recovery: replayed rows diverged from the clean "
                   "gateway — recovery is not lossless")
    fo = fr.get("failover", {})
    yield from require(
        fo, ["requests", "failed_over", "breaker_open", "completed", "failed", "lost",
             "bit_identical"],
        "$.fault_recovery.failover")
    if fo:
        if _metric(fo, "lost") != 0:
            yield (f"$.fault_recovery.failover: lost {fo.get('lost')!r} != 0 — "
                   "failover dropped a request")
        if not fo.get("breaker_open", False):
            yield "$.fault_recovery.failover: the dead engine's breaker is not Open"
        if not fo.get("bit_identical", False):
            yield ("$.fault_recovery.failover: re-homed rows diverged from the clean "
                   "gateway — failover is not lossless")
    if not doc.get("pjrt_skipped", True):
        for i, eng in enumerate(doc.get("engines", [])):
            yield from require(
                eng, ["name", "rank", "mode", "tokens_per_s", "decode_steps",
                      "ttft_p50_s", "kv_peak_bytes"],
                f"$.engines[{i}]")


def check_server(doc):
    yield from require(doc, ["bench", "preset", "stub_streaming", "skipped"])
    yield from require(
        doc.get("stub_streaming", {}),
        ["requests", "prompt_tokens", "completed", "mean_prefill_steps",
         "first_token_p50_s", "decode_steps"],
        "$.stub_streaming")
    if not doc.get("skipped", True):
        yield from require(doc, ["streaming", "cancel", "router"])
        yield from require(
            doc.get("streaming", {}),
            ["requests", "streaming_first_token_p50_s", "serve_all_delivery_s"],
            "$.streaming")
        yield from require(
            doc.get("cancel", {}),
            ["cancel_step", "waiter_started_step", "reclaim_steps"],
            "$.cancel")
        yield from require(doc.get("router", {}), ["requests", "engines"], "$.router")


def check_trace(doc):
    """Chrome trace-event documents (BENCH_trace.json, --trace-out dumps).

    Validates the shape Perfetto loads: every event carries name/ph/pid/
    tid/ts, complete ("X") events carry a non-negative dur, the step lane
    (pid 0) is time-ordered, and every closed request contributes exactly
    one complete span on its own (pid 1, tid=id) track, with any
    first-token instant mark landing inside that span.
    """
    yield from require(doc, ["traceEvents", "displayTimeUnit", "otherData"])
    events = doc.get("traceEvents", [])
    if not events:
        yield "$.traceEvents: empty — nothing was recorded"
    step_ts = []
    request_spans = {}  # tid -> (ts, dur)
    instants = []  # (tid, ts)
    for i, ev in enumerate(events):
        tag = f"$.traceEvents[{i}]"
        if not isinstance(ev, dict):
            yield f"{tag}: not an object"
            continue
        yield from require(ev, ["name", "ph", "pid", "tid", "ts"], tag)
        ts = _metric(ev, "ts")
        if ts is None or ts < 0:
            yield f"{tag}: ts {ev.get('ts')!r} is not a non-negative number"
            continue
        ph = ev.get("ph")
        if ph == "X":
            dur = _metric(ev, "dur")
            if dur is None or dur < 0:
                yield f"{tag}: dur {ev.get('dur')!r} is not a non-negative number"
                continue
            if ev.get("pid") == 0:
                step_ts.append(ts)
            elif ev.get("cat") == "request":
                tid = ev.get("tid")
                if tid in request_spans:
                    yield (f"{tag}: second complete span for request tid {tid!r} — "
                           "spans must be one per request")
                request_spans[tid] = (ts, dur)
        elif ph == "i" and ev.get("cat") == "request":
            instants.append((ev.get("tid"), ts, tag))
    for a, b in zip(step_ts, step_ts[1:]):
        if b < a:
            yield (f"$.traceEvents: step lane timestamps regress ({b} after {a}) — "
                   "the step ring is not time-ordered")
            break
    for tid, ts, tag in instants:
        span = request_spans.get(tid)
        if span is None:
            yield f"{tag}: first-token mark for tid {tid!r} has no request span"
        elif not (span[0] - 1 <= ts <= span[0] + span[1] + 1):  # 1us slack
            yield (f"{tag}: first-token mark at {ts} falls outside request "
                   f"{tid!r}'s span [{span[0]}, {span[0] + span[1]}]")
    other = doc.get("otherData", {})
    requests = other.get("requests")
    if isinstance(requests, (int, float)) and len(request_spans) > requests:
        yield (f"$.traceEvents: {len(request_spans)} request spans exceed "
               f"otherData.requests {requests}")
    steps_seen = other.get("steps_seen")
    if isinstance(steps_seen, (int, float)) and len(step_ts) > steps_seen:
        yield (f"$.traceEvents: {len(step_ts)} step events exceed "
               f"otherData.steps_seen {steps_seen}")


def check_metrics(doc):
    """Registry dumps (BENCH_metrics.json, --metrics-json): counters and
    gauges are flat series→number maps, histogram buckets are cumulative.
    """
    yield from require(doc, ["counters", "gauges", "histograms"])
    for kind in ("counters", "gauges"):
        for series, v in (doc.get(kind) or {}).items():
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                yield f"$.{kind}[{series!r}]: value {v!r} is not a number"
    for series, h in (doc.get("histograms") or {}).items():
        tag = f"$.histograms[{series!r}]"
        if not isinstance(h, dict):
            yield f"{tag}: not an object"
            continue
        yield from require(h, ["bounds", "counts", "sum", "count"], tag)
        counts = h.get("counts", [])
        if any(b < a for a, b in zip(counts, counts[1:])):
            yield f"{tag}: bucket counts are not cumulative (non-decreasing)"
        if counts and counts[-1] > h.get("count", 0):
            yield f"{tag}: last bucket {counts[-1]} exceeds total count {h.get('count')}"


CHECKERS = {
    "perf_serve": check_serve,
    "perf_server": check_server,
}

# Row-keyed sections a baseline snapshot is diffed over, as
# (section, list key, row match key).
BASELINE_SECTIONS = [
    ("prefill", "chunks", "chunk"),
    ("speculative", "sweep", "draft_len"),
    ("kv_codec", "codecs", "codec"),
    ("prefix_cache", "sweep", "share"),
    ("fault_recovery", "rates", "transient_rate"),
]
# Fresh value must keep >= 85% of the baseline (throughput-like metrics).
DOWN_METRICS = ["tokens_per_s", "max_concurrent_lanes", "tokens_per_s_cache_on",
                "prefix_hits", "goodput_vs_fault_free"]
# Fresh value must stay <= 115% of the baseline (work-per-token metrics;
# step counts are deterministic on the stub, so growth is a scheduling
# regression, not noise — and the prefix sweep runs on virtual time, so
# its TTFT is exact).
UP_METRICS = ["dense_steps_per_token", "prefill_steps", "decode_steps",
              "ttft_mean_cache_on_s"]


def _metric(row, key):
    """The row's value for `key` if it is a usable number, else None."""
    v = row.get(key)
    if isinstance(v, bool) or not isinstance(v, (int, float)) or not math.isfinite(v):
        return None
    return v


def check_baseline(doc, base):
    """Yield errors for >15% regressions against a baseline snapshot.

    Rows are matched by section-specific key; baseline rows or metric
    values that are missing or null are skipped (the bootstrap snapshot
    is schema-complete but carries null measurements until a toolchain
    run fills them).
    """
    for section, list_key, match_key in BASELINE_SECTIONS:
        base_sec = base.get(section) or {}
        base_rows = {row.get(match_key): row
                     for row in base_sec.get(list_key, []) if isinstance(row, dict)}
        doc_sec = doc.get(section) or {}
        for row in doc_sec.get(list_key, []):
            if not isinstance(row, dict):
                continue
            b = base_rows.get(row.get(match_key))
            if b is None:
                continue
            tag = f"$.{section}.{list_key}[{match_key}={row.get(match_key)!r}]"
            for key in DOWN_METRICS:
                bv, fv = _metric(b, key), _metric(row, key)
                if bv is not None and bv > 0 and fv is not None and fv < 0.85 * bv:
                    yield (f"{tag}: {key} {fv:g} fell below 85% of the baseline "
                           f"{bv:g} ({100.0 * fv / bv:.0f}%)")
            for key in UP_METRICS:
                bv, fv = _metric(b, key), _metric(row, key)
                if bv is not None and bv > 0 and fv is not None and fv > 1.15 * bv:
                    yield (f"{tag}: {key} {fv:g} rose above 115% of the baseline "
                           f"{bv:g} ({100.0 * fv / bv:.0f}%)")


def main(argv):
    baseline_path = None
    paths = []
    it = iter(argv)
    for arg in it:
        if arg == "--baseline":
            baseline_path = next(it, None)
            if baseline_path is None:
                print("--baseline requires a snapshot path")
                return 2
        else:
            paths.append(arg)
    base_doc = None
    if baseline_path is not None:
        try:
            with open(baseline_path) as f:
                base_doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"FAIL {baseline_path}: unreadable baseline: {e}")
            return 1
    failed = False
    for path in paths:
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"FAIL {path}: {e}")
            failed = True
            continue
        bench = doc.get("bench")
        errors = []
        if bench is None and "traceEvents" in doc:
            # Chrome trace-event dumps carry no bench id; dispatch on shape.
            bench = "trace"
            errors.extend(check_trace(doc))
        elif bench is None and "counters" in doc and "gauges" in doc:
            bench = "metrics"
            errors.extend(check_metrics(doc))
        else:
            checker = CHECKERS.get(bench)
            if checker is None:
                errors.append(f"$: unknown or missing bench id {bench!r}")
            else:
                errors.extend(checker(doc))
        errors.extend(finite_numbers(doc))
        if base_doc is not None and bench == base_doc.get("bench"):
            errors.extend(check_baseline(doc, base_doc))
        if errors:
            failed = True
            print(f"FAIL {path}:")
            for e in errors:
                print(f"  {e}")
        else:
            print(f"OK   {path} ({bench})")
    return 1 if failed else 0


if __name__ == "__main__":
    if len(sys.argv) < 2:
        print(__doc__)
        sys.exit(2)
    sys.exit(main(sys.argv[1:]))
