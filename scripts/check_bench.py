#!/usr/bin/env python3
"""Sanity-check emitted BENCH_*.json artifacts against their schemas.

The perf benches (`cargo bench --bench perf_serve` / `perf_server`) write
machine-readable JSON so the serving-perf trajectory is comparable across
PRs.  This checker enforces the contract documented in
docs/BENCH_SCHEMAS.md: the required keys are present and every number is
finite (a NaN tokens/s or an Infinity TTFT means a bench divided by a
zero wall-clock — a bug, not a measurement).

Usage:  python3 scripts/check_bench.py rust/BENCH_serve.json rust/BENCH_server.json

Exit code 0 when every file passes; 1 with a per-file report otherwise.
Stdlib only — runs anywhere CI has a python3.
"""

from __future__ import annotations

import json
import math
import sys


def finite_numbers(node, path="$"):
    """Yield an error string for every non-finite number in the tree."""
    if isinstance(node, bool):
        return
    if isinstance(node, (int, float)):
        if not math.isfinite(node):
            yield f"{path}: non-finite number {node!r}"
    elif isinstance(node, dict):
        for k, v in node.items():
            yield from finite_numbers(v, f"{path}.{k}")
    elif isinstance(node, list):
        for i, v in enumerate(node):
            yield from finite_numbers(v, f"{path}[{i}]")


def require(doc, keys, path="$"):
    for k in keys:
        if k not in doc:
            yield f"{path}: missing required key {k!r}"


def check_serve(doc):
    yield from require(doc, ["bench", "preset", "prefill", "speculative", "engines",
                             "pjrt_skipped"])
    prefill = doc.get("prefill", {})
    yield from require(prefill, ["backend", "prompt_tokens", "ladder", "chunks"],
                       "$.prefill")
    chunks = prefill.get("chunks", [])
    if not chunks:
        yield "$.prefill.chunks: empty — the chunk ladder was not benched"
    for i, row in enumerate(chunks):
        yield from require(
            row,
            ["chunk", "prefill_steps", "decode_steps", "ttft_p50_s", "tokens_per_s",
             "prefill_step_reduction_vs_k1"],
            f"$.prefill.chunks[{i}]")
    # The acceptance bar: some chunk width >= 8 cuts prefill steps >= 4x
    # vs the single-token path.
    reductions = [row.get("prefill_step_reduction_vs_k1", 0)
                  for row in chunks if row.get("chunk", 0) >= 8]
    if reductions and max(reductions) < 4:
        yield (f"$.prefill: best prefill step reduction {max(reductions)}x < 4x "
               "for a chunked width")
    spec = doc.get("speculative", {})
    yield from require(
        spec, ["backend", "target_rank", "draft_rank", "vanilla_steps_per_token", "sweep"],
        "$.speculative")
    sweep = spec.get("sweep", [])
    if not sweep:
        yield "$.speculative.sweep: empty — the draft-length sweep was not benched"
    for i, row in enumerate(sweep):
        yield from require(
            row,
            ["draft_len", "acceptance_rate", "dense_steps_per_token", "draft_steps",
             "rollback_tokens", "bit_identical_to_vanilla"],
            f"$.speculative.sweep[{i}]")
        if not row.get("bit_identical_to_vanilla", False):
            yield (f"$.speculative.sweep[{i}]: speculative greedy output diverged from "
                   "vanilla greedy decode — the bit-identity invariant is broken")
    # The acceptance bar: some draft length >= 4 runs the dense decode at
    # < 1.0 steps per generated token — and strictly beats the vanilla
    # trace (vanilla sits at ~1.0 minus the prefill-boundary token, so
    # beating it is the part that proves speculation pays).
    vanilla = spec.get("vanilla_steps_per_token", 1.0)
    spt = [row.get("dense_steps_per_token", 1.0)
           for row in sweep if row.get("draft_len", 0) >= 4]
    if spt and min(spt) >= 1.0:
        yield (f"$.speculative: best dense steps-per-token {min(spt)} >= 1.0 at "
               "draft length >= 4 — speculation is not paying for itself")
    if spt and min(spt) >= vanilla:
        yield (f"$.speculative: best dense steps-per-token {min(spt)} does not "
               f"beat the vanilla trace ({vanilla})")
    if not doc.get("pjrt_skipped", True):
        for i, eng in enumerate(doc.get("engines", [])):
            yield from require(
                eng, ["name", "rank", "mode", "tokens_per_s", "decode_steps",
                      "ttft_p50_s", "kv_peak_bytes"],
                f"$.engines[{i}]")


def check_server(doc):
    yield from require(doc, ["bench", "preset", "stub_streaming", "skipped"])
    yield from require(
        doc.get("stub_streaming", {}),
        ["requests", "prompt_tokens", "completed", "mean_prefill_steps",
         "first_token_p50_s", "decode_steps"],
        "$.stub_streaming")
    if not doc.get("skipped", True):
        yield from require(doc, ["streaming", "cancel", "router"])
        yield from require(
            doc.get("streaming", {}),
            ["requests", "streaming_first_token_p50_s", "serve_all_delivery_s"],
            "$.streaming")
        yield from require(
            doc.get("cancel", {}),
            ["cancel_step", "waiter_started_step", "reclaim_steps"],
            "$.cancel")
        yield from require(doc.get("router", {}), ["requests", "engines"], "$.router")


CHECKERS = {
    "perf_serve": check_serve,
    "perf_server": check_server,
}


def main(paths):
    failed = False
    for path in paths:
        try:
            with open(path) as f:
                doc = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"FAIL {path}: {e}")
            failed = True
            continue
        bench = doc.get("bench")
        checker = CHECKERS.get(bench)
        errors = []
        if checker is None:
            errors.append(f"$: unknown or missing bench id {bench!r}")
        else:
            errors.extend(checker(doc))
        errors.extend(finite_numbers(doc))
        if errors:
            failed = True
            print(f"FAIL {path}:")
            for e in errors:
                print(f"  {e}")
        else:
            print(f"OK   {path} ({bench})")
    return 1 if failed else 0


if __name__ == "__main__":
    if len(sys.argv) < 2:
        print(__doc__)
        sys.exit(2)
    sys.exit(main(sys.argv[1:]))
